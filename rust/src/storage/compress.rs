//! Zero-dependency LZ4-style block compression for the disk tier.
//!
//! The format is the classic byte-oriented LZ77 token stream: each
//! *sequence* is `[token][literal-len ext…][literals][offset u16 LE]
//! [match-len ext…]`, where the token's high nibble is the literal count
//! and the low nibble is `match_len - MIN_MATCH` (both extended by 255-run
//! bytes when the nibble saturates at 15). Matches are at least
//! [`MIN_MATCH`] bytes and reference a window of up to 64 KiB back. The
//! final sequence carries literals only (no offset/match) — exactly the
//! LZ4 block convention, so the framing cost on incompressible data is
//! ~0.4%.
//!
//! Design constraints, in order:
//!
//! 1. **Never panic on corrupt input.** [`decompress`] is fully
//!    bounds-checked and returns [`CorruptBlock`] on any malformed
//!    stream; the disk tier maps that to an I/O error plus a checksum
//!    failure counter tick.
//! 2. **Byte-exact round trip** for every input, including empty,
//!    incompressible, and pathological ones (property-tested in
//!    `tests/property_suite.rs`).
//! 3. **Speed over ratio**: one greedy pass, a fixed 4 Ki-entry hash
//!    table on the stack-ish heap, no entropy stage. On the Zipf word
//!    corpora the spill runs compress ~2-4×, which is what moves the
//!    spill cliff — a stronger coder would spend the wall we just saved.

/// Shortest match worth encoding (the token's low nibble is
/// `len - MIN_MATCH`).
const MIN_MATCH: usize = 4;

/// Match window: offsets are stored as `u16`, so references reach at most
/// 64 KiB - 1 bytes back.
const MAX_OFFSET: usize = 0xFFFF;

/// Hash-table size (log2). 4 Ki entries × 4 B = 16 KiB scratch per call.
const HASH_BITS: u32 = 12;

/// Fibonacci hashing of the next 4 bytes — the standard LZ4 multiplier.
#[inline]
fn hash4(v: u32) -> usize {
    (v.wrapping_mul(2_654_435_761) >> (32 - HASH_BITS)) as usize
}

#[inline]
fn read_u32_le(src: &[u8], i: usize) -> u32 {
    u32::from_le_bytes([src[i], src[i + 1], src[i + 2], src[i + 3]])
}

/// Append a length in LZ4 nibble-plus-255-extensions form: the caller has
/// already written the nibble (`min(len, 15)`); this emits the extension
/// bytes for `len >= 15`.
#[inline]
fn push_len_ext(dst: &mut Vec<u8>, mut len: usize) {
    if len < 15 {
        return;
    }
    len -= 15;
    while len >= 255 {
        dst.push(255);
        len -= 255;
    }
    dst.push(len as u8);
}

/// Compress `src`, appending the block to `dst`. Returns the number of
/// compressed bytes appended. The output carries no length framing — the
/// caller (the disk tier's frame table) records both raw and compressed
/// lengths externally.
pub fn compress(src: &[u8], dst: &mut Vec<u8>) -> usize {
    let start = dst.len();
    let n = src.len();
    // Matches must leave 5 bytes of tail literals (LZ4's end-of-block
    // rule; also guarantees the 4-byte hash read below never overruns).
    let match_limit = n.saturating_sub(5);

    let mut table = vec![u32::MAX; 1 << HASH_BITS];
    let mut i = 0usize; // cursor
    let mut anchor = 0usize; // start of pending literals

    while i < match_limit {
        let seq = read_u32_le(src, i);
        let slot = hash4(seq);
        let cand = table[slot] as usize;
        table[slot] = i as u32;

        let found = cand != u32::MAX as usize
            && i - cand <= MAX_OFFSET
            && read_u32_le(src, cand) == seq;
        if !found {
            i += 1;
            continue;
        }

        // Extend the match as far as the end-of-block rule allows.
        let mut mlen = MIN_MATCH;
        while i + mlen < match_limit && src[cand + mlen] == src[i + mlen] {
            mlen += 1;
        }

        let lit = i - anchor;
        let token = ((lit.min(15) as u8) << 4) | ((mlen - MIN_MATCH).min(15) as u8);
        dst.push(token);
        push_len_ext(dst, lit);
        dst.extend_from_slice(&src[anchor..i]);
        dst.extend_from_slice(&((i - cand) as u16).to_le_bytes());
        push_len_ext(dst, mlen - MIN_MATCH);

        i += mlen;
        anchor = i;
    }

    // Final sequence: remaining literals, no match.
    let lit = n - anchor;
    dst.push((lit.min(15) as u8) << 4);
    push_len_ext(dst, lit);
    dst.extend_from_slice(&src[anchor..]);

    dst.len() - start
}

/// Decompression failure: the stream is malformed (truncated, offset out
/// of window, or the decoded length disagrees with the expected one).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CorruptBlock;

impl std::fmt::Display for CorruptBlock {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str("corrupt compressed block")
    }
}

impl std::error::Error for CorruptBlock {}

/// Read a nibble-extended length: `nibble` came from the token; consume
/// 255-run extension bytes if it saturated.
#[inline]
fn read_len_ext(src: &[u8], pos: &mut usize, nibble: usize) -> Result<usize, CorruptBlock> {
    let mut len = nibble;
    if nibble == 15 {
        loop {
            let b = *src.get(*pos).ok_or(CorruptBlock)?;
            *pos += 1;
            len += b as usize;
            if b != 255 {
                break;
            }
        }
    }
    Ok(len)
}

/// Decompress a block produced by [`compress`] into a fresh buffer of
/// exactly `expected_len` bytes. Every read is bounds-checked; any
/// malformed stream — truncated sequence, zero or out-of-window offset,
/// or a decoded length other than `expected_len` — yields
/// `Err(CorruptBlock)`, never a panic.
pub fn decompress(src: &[u8], expected_len: usize) -> Result<Vec<u8>, CorruptBlock> {
    let mut out = Vec::with_capacity(expected_len);
    let mut pos = 0usize;

    loop {
        let token = *src.get(pos).ok_or(CorruptBlock)?;
        pos += 1;

        // Literals.
        let lit = read_len_ext(src, &mut pos, (token >> 4) as usize)?;
        let lit_end = pos.checked_add(lit).ok_or(CorruptBlock)?;
        if lit_end > src.len() {
            return Err(CorruptBlock);
        }
        out.extend_from_slice(&src[pos..lit_end]);
        pos = lit_end;
        if out.len() > expected_len {
            return Err(CorruptBlock);
        }

        // The final sequence is literals-only: the stream simply ends.
        if pos == src.len() {
            break;
        }

        // Match copy.
        if pos + 2 > src.len() {
            return Err(CorruptBlock);
        }
        let offset = u16::from_le_bytes([src[pos], src[pos + 1]]) as usize;
        pos += 2;
        if offset == 0 || offset > out.len() {
            return Err(CorruptBlock);
        }
        let mlen = read_len_ext(src, &mut pos, (token & 0x0F) as usize)? + MIN_MATCH;
        if out.len() + mlen > expected_len {
            return Err(CorruptBlock);
        }
        // Byte-by-byte so overlapping copies (offset < mlen, the RLE
        // case) replicate correctly.
        let mut from = out.len() - offset;
        for _ in 0..mlen {
            let b = out[from];
            out.push(b);
            from += 1;
        }
    }

    if out.len() != expected_len {
        return Err(CorruptBlock);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(src: &[u8]) -> usize {
        let mut enc = Vec::new();
        let n = compress(src, &mut enc);
        assert_eq!(n, enc.len());
        let dec = decompress(&enc, src.len()).expect("roundtrip decode");
        assert_eq!(dec, src, "roundtrip mismatch for {} bytes", src.len());
        n
    }

    #[test]
    fn empty_input_roundtrips() {
        assert_eq!(roundtrip(b""), 1); // a lone zero token
    }

    #[test]
    fn tiny_inputs_roundtrip() {
        for n in 1..=32usize {
            let data: Vec<u8> = (0..n as u8).collect();
            roundtrip(&data);
        }
    }

    #[test]
    fn repetitive_input_compresses_hard() {
        let data = b"the quick brown fox ".repeat(512);
        let n = roundtrip(&data);
        assert!(
            n * 10 < data.len(),
            "expected >10x on pure repetition, got {n}/{}",
            data.len()
        );
    }

    #[test]
    fn zipf_like_text_compresses() {
        // Skewed word stream — the shape of our spill payloads.
        let words = ["the", "of", "and", "to", "in", "analysis", "spark", "mpi"];
        let mut data = Vec::new();
        let mut state = 0x9e3779b97f4a7c15u64;
        for _ in 0..4000 {
            state = state.wrapping_mul(6364136223846793005).wrapping_add(1);
            let r = (state >> 33) as usize;
            // Zipf-ish: low indices much more likely.
            let idx = (r % 64).min(7).min(r % 8);
            data.extend_from_slice(words[idx].as_bytes());
            data.push(b' ');
        }
        let n = roundtrip(&data);
        assert!(n * 2 < data.len(), "expected >2x on skewed text, got {n}/{}", data.len());
    }

    #[test]
    fn incompressible_input_expands_bounded() {
        // Pseudo-random bytes: no 4-byte match should survive, so the
        // output is literals plus ~1 byte of framing per 255-byte run.
        let mut data = vec![0u8; 4096];
        let mut state = 0x2545f4914f6cdd1du64;
        for b in data.iter_mut() {
            state ^= state << 13;
            state ^= state >> 7;
            state ^= state << 17;
            *b = (state >> 24) as u8;
        }
        let n = roundtrip(&data);
        assert!(n <= data.len() + data.len() / 128 + 16, "expansion too large: {n}");
    }

    #[test]
    fn overlapping_match_rle_case() {
        // Single repeated byte forces offset=1 overlapping copies.
        roundtrip(&[0xAB; 1000]);
        // Period-3 pattern: offset 3 < match len.
        let data: Vec<u8> = (0..999).map(|i| [1u8, 2, 3][i % 3]).collect();
        roundtrip(&data);
    }

    #[test]
    fn long_literal_and_match_extensions() {
        // >15 literals then >15+4 match, exercising the 255-run extension
        // bytes on both nibbles.
        let mut data: Vec<u8> = (0u16..600).map(|i| (i % 251) as u8).collect();
        let tail = data.clone();
        data.extend_from_slice(&tail); // one huge match
        roundtrip(&data);
    }

    #[test]
    fn corrupt_streams_error_not_panic() {
        let data = b"hello hello hello hello hello hello".repeat(8);
        let mut enc = Vec::new();
        compress(&data, &mut enc);

        // Wrong expected length.
        assert_eq!(decompress(&enc, data.len() + 1), Err(CorruptBlock));
        assert_eq!(decompress(&enc, data.len().saturating_sub(1)), Err(CorruptBlock));

        // Truncations at every prefix must not panic.
        for cut in 0..enc.len() {
            let _ = decompress(&enc[..cut], data.len());
        }

        // Single-byte corruption at every position must not panic.
        for i in 0..enc.len() {
            let mut bad = enc.clone();
            bad[i] ^= 0xFF;
            let _ = decompress(&bad, data.len());
        }

        // Empty stream is not a valid block (a block always has >= 1
        // token byte).
        assert_eq!(decompress(b"", 0), Err(CorruptBlock));
        assert_eq!(decompress(b"", 5), Err(CorruptBlock));
    }

    #[test]
    fn zero_offset_is_rejected() {
        // token: 0 literals, match nibble 0 (=> len 4), offset 0.
        let stream = [0x00u8, 0x00, 0x00];
        assert_eq!(decompress(&stream, 4), Err(CorruptBlock));
    }
}
