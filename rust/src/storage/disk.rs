//! `DiskTier` — the disk tier of the storage hierarchy: checksummed
//! block files in a per-instance temp directory.
//!
//! One `DiskTier` serves one job (or one shared cache): spill runs,
//! demoted cache entries, and persisted shuffle blocks all write through
//! the same instance, so the job's disk traffic lands in one
//! [`StorageCounters`] cell (see the namespace map in the module docs).
//! The directory is created lazily on the first write — constructing a
//! tier costs nothing until something actually spills — and removed on
//! drop (generation-aware cleanup for long-lived tiers goes through
//! [`BlockStore::delete_generations_below`]).
//!
//! # On-disk format
//!
//! Every block file starts with a 17-byte header:
//! `[payload_len: u64 LE][fnv1a checksum: u64 LE][codec: u8]`, where both
//! length and checksum describe the **logical** (uncompressed) payload.
//! The payload region depends on the codec byte:
//!
//! * Codec 0 (raw): the logical payload verbatim. Chosen when the tier's
//!   compression knob is off, when the payload is too small to be worth
//!   framing, or when compression failed to shrink the block overall.
//! * Codec 1 (framed LZ4): `[frame_count: u32 LE]` followed by a
//!   `(raw_len: u32 LE, comp_len: u32 LE)` table entry per frame, then
//!   the frame bodies back to back. The logical payload is split into
//!   fixed [`RAW_FRAME`]-byte frames (last one partial) compressed
//!   independently with [`compress`], so [`BlockStore::read_range`] can
//!   serve any logical offset by decoding a single frame. A frame whose
//!   compressed form would expand is stored raw, signalled by
//!   `comp_len == raw_len`.
//!
//! Offsets in `read_range` and [`BlockMeta`] always address the
//! *logical* payload; `bytes_stored` and the disk byte counters report
//! *stored* (post-compression) bytes. Full reads verify the logical
//! checksum; range reads (the external-merge cursors) accumulate it
//! incrementally and verify at end-of-run against [`BlockStore::meta`].

use std::collections::HashMap;
use std::io::{Read, Seek, SeekFrom, Write};
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering::Relaxed};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::cache::CacheKey;
use crate::trace::{self, SpanCat};

use super::{checksum, compress, BlockMeta, BlockStore, StorageCounters, CHECKSUM_SEED};

/// Bytes of on-disk header before the payload region.
const HEADER_LEN: u64 = 17;

/// Codec byte: payload region is the logical payload verbatim.
const CODEC_RAW: u8 = 0;

/// Codec byte: payload region is a frame table plus LZ4-style frames.
const CODEC_LZ4: u8 = 1;

/// Raw bytes per compression frame. Matches the spill cursor's read
/// chunk, so sequential run reads decode each frame exactly once.
const RAW_FRAME: usize = 64 << 10;

/// Bytes per frame-table entry: `(raw_len: u32, comp_len: u32)`.
const FRAME_ENTRY: usize = 8;

/// Blocks smaller than this skip compression outright — the frame table
/// alone would eat any plausible win.
const MIN_COMPRESS_LEN: usize = 64;

/// Process-wide uniquifier for tier directories (two tiers in one
/// process — a job's spill tier and a shared cache's — must not share a
/// directory even under the same base path).
static NEXT_DIR_ID: AtomicU64 = AtomicU64::new(0);

/// Index entry: logical metadata plus the stored-form description. The
/// frame table is kept in memory so range reads seek straight to the
/// right frame without re-reading the on-disk table.
#[derive(Clone)]
struct StoredBlock {
    meta: BlockMeta,
    /// Payload-region bytes on disk (excluding the header).
    stored_len: u64,
    codec: u8,
    /// `(raw_len, comp_len)` per frame; empty for [`CODEC_RAW`].
    frames: Arc<Vec<(u32, u32)>>,
}

struct Index {
    blocks: HashMap<CacheKey, StoredBlock>,
    bytes: u64,
    /// Created lazily on first write; `None` until then.
    dir: Option<PathBuf>,
}

/// The disk tier (see module docs). Thread-safe; share as
/// `Arc<DiskTier>` (or `Arc<dyn BlockStore>`).
pub struct DiskTier {
    /// Base directory the tier's own subdirectory is created under
    /// (`None` = the system temp dir) — the `--spill-dir` knob.
    base: Option<PathBuf>,
    /// Attempt framed compression on writes (the `--compress` knob).
    compress: bool,
    index: Mutex<Index>,
    counters: Arc<StorageCounters>,
}

impl std::fmt::Debug for DiskTier {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let index = self.index.lock().unwrap();
        f.debug_struct("DiskTier")
            .field("dir", &index.dir)
            .field("blocks", &index.blocks.len())
            .field("bytes", &index.bytes)
            .finish()
    }
}

impl DiskTier {
    /// A tier with its own fresh [`StorageCounters`] cell.
    pub fn new(base: Option<PathBuf>) -> Self {
        Self::with_counters(base, Arc::new(StorageCounters::default()))
    }

    /// A tier recording into an externally owned counters cell.
    pub fn with_counters(base: Option<PathBuf>, counters: Arc<StorageCounters>) -> Self {
        Self {
            base,
            compress: true,
            index: Mutex::new(Index { blocks: HashMap::new(), bytes: 0, dir: None }),
            counters,
        }
    }

    /// Toggle block compression (on by default; `--compress off` is the
    /// ablation arm). Existing blocks keep whatever codec they were
    /// written with — the codec byte travels with each block.
    pub fn compression(mut self, on: bool) -> Self {
        self.compress = on;
        self
    }

    /// The counters cell this tier (and its co-clients) record into.
    pub fn counters(&self) -> &Arc<StorageCounters> {
        &self.counters
    }

    /// The tier's directory, if anything was ever written.
    pub fn dir(&self) -> Option<PathBuf> {
        self.index.lock().unwrap().dir.clone()
    }

    fn file_name(key: &CacheKey) -> String {
        format!(
            "ns{:x}-g{}-p{:x}-s{}.blk",
            key.namespace, key.generation, key.partition, key.splits
        )
    }

    /// The directory, creating it on first use.
    fn ensure_dir(index: &mut Index, base: &Option<PathBuf>) -> std::io::Result<PathBuf> {
        if let Some(dir) = &index.dir {
            return Ok(dir.clone());
        }
        let parent = base.clone().unwrap_or_else(std::env::temp_dir);
        let dir = parent.join(format!(
            "blaze-tier-{}-{}",
            std::process::id(),
            NEXT_DIR_ID.fetch_add(1, Relaxed)
        ));
        std::fs::create_dir_all(&dir)?;
        index.dir = Some(dir.clone());
        Ok(dir)
    }

    fn remove_file(index: &Index, key: &CacheKey) {
        if let Some(dir) = &index.dir {
            let _ = std::fs::remove_file(dir.join(Self::file_name(key)));
        }
    }

    /// Drop every block in the tier (counters are kept). Only safe for
    /// tiers with a single client — callers sharing a tier retire their
    /// own keys via [`BlockStore::delete`] /
    /// [`BlockStore::delete_generations_below`] instead.
    pub fn clear_all(&self) {
        let mut index = self.index.lock().unwrap();
        let victims: Vec<CacheKey> = index.blocks.keys().copied().collect();
        for key in &victims {
            index.blocks.remove(key);
            Self::remove_file(&index, key);
        }
        index.bytes = 0;
    }

    /// Compress `payload` into frames. Returns `(frames, body)`, or
    /// `None` when framing would not shrink the block overall.
    fn encode_frames(&self, payload: &[u8]) -> Option<(Vec<(u32, u32)>, Vec<u8>)> {
        if !self.compress || payload.len() < MIN_COMPRESS_LEN {
            return None;
        }
        let _span = trace::span_arg(SpanCat::Compress, "block-compress", payload.len() as u64);
        let t0 = Instant::now();
        let mut frames: Vec<(u32, u32)> = Vec::with_capacity(payload.len().div_ceil(RAW_FRAME));
        let mut body: Vec<u8> = Vec::with_capacity(payload.len() / 2);
        for chunk in payload.chunks(RAW_FRAME) {
            let before = body.len();
            let n = compress::compress(chunk, &mut body);
            if n >= chunk.len() {
                // An incompressible frame is stored raw (comp == raw).
                body.truncate(before);
                body.extend_from_slice(chunk);
                frames.push((chunk.len() as u32, chunk.len() as u32));
            } else {
                frames.push((chunk.len() as u32, n as u32));
            }
        }
        let stored = 4 + FRAME_ENTRY * frames.len() + body.len();
        if stored < payload.len() {
            self.counters.record_compress(payload.len() as u64, stored as u64, t0.elapsed());
            Some((frames, body))
        } else {
            // Record the attempt (ratio 1.0) and fall back to raw.
            self.counters.record_compress(
                payload.len() as u64,
                payload.len() as u64,
                t0.elapsed(),
            );
            None
        }
    }

    /// Decompress one frame read off disk, mapping corruption to the
    /// tier's graceful `InvalidData` error.
    fn decode_frame(
        &self,
        key: &CacheKey,
        buf: Vec<u8>,
        raw_len: u32,
        comp_len: u32,
    ) -> std::io::Result<Vec<u8>> {
        if comp_len == raw_len {
            return Ok(buf);
        }
        let _span = trace::span_arg(SpanCat::Decompress, "frame-decompress", raw_len as u64);
        let t0 = Instant::now();
        match compress::decompress(&buf, raw_len as usize) {
            Ok(frame) => {
                self.counters.record_decompress(t0.elapsed());
                Ok(frame)
            }
            Err(_) => {
                self.counters.record_checksum_failure();
                Err(std::io::Error::new(
                    std::io::ErrorKind::InvalidData,
                    format!("block {key:?} has a corrupt compressed frame"),
                ))
            }
        }
    }
}

impl BlockStore for DiskTier {
    fn write(&self, key: CacheKey, payload: &[u8]) -> std::io::Result<u64> {
        let meta = BlockMeta {
            payload_len: payload.len() as u64,
            checksum: checksum(CHECKSUM_SEED, payload),
        };
        let encoded = self.encode_frames(payload);
        let (codec, stored_len) = match &encoded {
            Some((frames, body)) => {
                (CODEC_LZ4, (4 + FRAME_ENTRY * frames.len() + body.len()) as u64)
            }
            None => (CODEC_RAW, payload.len() as u64),
        };
        let t0 = Instant::now();
        let path = {
            let mut index = self.index.lock().unwrap();
            let dir = Self::ensure_dir(&mut index, &self.base)?;
            dir.join(Self::file_name(&key))
        };
        let mut f = std::fs::File::create(&path)?;
        f.write_all(&meta.payload_len.to_le_bytes())?;
        f.write_all(&meta.checksum.to_le_bytes())?;
        f.write_all(&[codec])?;
        match &encoded {
            Some((frames, body)) => {
                let mut table = Vec::with_capacity(4 + FRAME_ENTRY * frames.len());
                table.extend_from_slice(&(frames.len() as u32).to_le_bytes());
                for &(raw, comp) in frames {
                    table.extend_from_slice(&raw.to_le_bytes());
                    table.extend_from_slice(&comp.to_le_bytes());
                }
                f.write_all(&table)?;
                f.write_all(body)?;
            }
            None => f.write_all(payload)?,
        }
        f.flush()?;
        let frames = encoded.map(|(frames, _)| frames).unwrap_or_default();
        let bytes_now = {
            let mut index = self.index.lock().unwrap();
            let block = StoredBlock { meta, stored_len, codec, frames: Arc::new(frames) };
            if let Some(old) = index.blocks.insert(key, block) {
                index.bytes -= old.stored_len;
            }
            index.bytes += stored_len;
            index.bytes
        };
        self.counters.record_disk_write(stored_len, t0.elapsed());
        trace::counter("disk stored bytes", bytes_now);
        Ok(meta.payload_len)
    }

    fn read(&self, key: &CacheKey) -> std::io::Result<Option<Vec<u8>>> {
        let t0 = Instant::now();
        let (path, block) = {
            let index = self.index.lock().unwrap();
            let Some(block) = index.blocks.get(key).cloned() else {
                return Ok(None);
            };
            let dir = index.dir.clone().expect("indexed block without a tier dir");
            (dir.join(Self::file_name(key)), block)
        };
        let mut f = std::fs::File::open(&path)?;
        let mut header = [0u8; HEADER_LEN as usize];
        f.read_exact(&mut header)?;
        let stored_len = u64::from_le_bytes(header[0..8].try_into().unwrap());
        let stored_sum = u64::from_le_bytes(header[8..16].try_into().unwrap());
        // Validate the (untrusted) on-disk header against the trusted
        // in-memory index *before* sizing any allocation from it — a
        // corrupt length must surface as the graceful InvalidData error,
        // not an OOM.
        if stored_len != block.meta.payload_len
            || stored_sum != block.meta.checksum
            || header[16] != block.codec
        {
            self.counters.record_checksum_failure();
            return Err(std::io::Error::new(
                std::io::ErrorKind::InvalidData,
                format!("block {key:?} has a corrupt header"),
            ));
        }
        let payload = if block.codec == CODEC_LZ4 {
            // The in-memory frame table is the trusted copy; skip the
            // on-disk one and stream the frame bodies.
            let table = (4 + FRAME_ENTRY * block.frames.len()) as u64;
            f.seek(SeekFrom::Start(HEADER_LEN + table))?;
            let mut payload = Vec::with_capacity(block.meta.payload_len as usize);
            let mut buf = Vec::new();
            for &(raw, comp) in block.frames.iter() {
                buf.resize(comp as usize, 0);
                f.read_exact(&mut buf)?;
                let frame = self.decode_frame(key, std::mem::take(&mut buf), raw, comp)?;
                payload.extend_from_slice(&frame);
            }
            payload
        } else {
            let mut payload = Vec::with_capacity(block.meta.payload_len as usize);
            f.read_to_end(&mut payload)?;
            payload
        };
        let ok = payload.len() as u64 == block.meta.payload_len
            && checksum(CHECKSUM_SEED, &payload) == block.meta.checksum;
        if !ok {
            self.counters.record_checksum_failure();
            return Err(std::io::Error::new(
                std::io::ErrorKind::InvalidData,
                format!("block {key:?} failed checksum verification"),
            ));
        }
        self.counters.record_disk_read(block.stored_len, t0.elapsed());
        Ok(Some(payload))
    }

    fn read_range(
        &self,
        key: &CacheKey,
        offset: u64,
        max_len: usize,
    ) -> std::io::Result<Option<Vec<u8>>> {
        let t0 = Instant::now();
        let (path, block) = {
            let index = self.index.lock().unwrap();
            let Some(block) = index.blocks.get(key).cloned() else {
                return Ok(None);
            };
            let dir = index.dir.clone().expect("indexed block without a tier dir");
            (dir.join(Self::file_name(key)), block)
        };
        if offset >= block.meta.payload_len {
            return Ok(Some(Vec::new()));
        }
        if block.codec == CODEC_LZ4 {
            // One frame covers any logical offset; a read capped at the
            // frame boundary is a legal short return (the cursor's
            // contract only requires non-empty progress).
            let frame_idx = (offset / RAW_FRAME as u64) as usize;
            let (raw, comp) = block.frames[frame_idx];
            let table = (4 + FRAME_ENTRY * block.frames.len()) as u64;
            let skip: u64 = block.frames[..frame_idx].iter().map(|&(_, c)| c as u64).sum();
            let mut f = std::fs::File::open(&path)?;
            f.seek(SeekFrom::Start(HEADER_LEN + table + skip))?;
            let mut buf = vec![0u8; comp as usize];
            f.read_exact(&mut buf)?;
            let frame = self.decode_frame(key, buf, raw, comp)?;
            let inner = (offset - frame_idx as u64 * RAW_FRAME as u64) as usize;
            let end = frame.len().min(inner + max_len);
            self.counters.record_disk_read(comp as u64, t0.elapsed());
            Ok(Some(frame[inner..end].to_vec()))
        } else {
            let want = max_len.min((block.meta.payload_len - offset) as usize);
            let mut f = std::fs::File::open(&path)?;
            f.seek(SeekFrom::Start(HEADER_LEN + offset))?;
            let mut buf = vec![0u8; want];
            f.read_exact(&mut buf)?;
            self.counters.record_disk_read(want as u64, t0.elapsed());
            Ok(Some(buf))
        }
    }

    fn meta(&self, key: &CacheKey) -> Option<BlockMeta> {
        self.index.lock().unwrap().blocks.get(key).map(|b| b.meta)
    }

    fn delete(&self, key: &CacheKey) -> bool {
        let mut index = self.index.lock().unwrap();
        match index.blocks.remove(key) {
            Some(block) => {
                index.bytes -= block.stored_len;
                Self::remove_file(&index, key);
                true
            }
            None => false,
        }
    }

    fn delete_generations_below(&self, namespace: u64, keep_generation: u64) -> usize {
        let mut index = self.index.lock().unwrap();
        let victims: Vec<CacheKey> = index
            .blocks
            .keys()
            .filter(|k| k.namespace == namespace && k.generation < keep_generation)
            .copied()
            .collect();
        for key in &victims {
            let block = index.blocks.remove(key).unwrap();
            index.bytes -= block.stored_len;
            Self::remove_file(&index, key);
        }
        victims.len()
    }

    fn len(&self) -> usize {
        self.index.lock().unwrap().blocks.len()
    }

    fn bytes_stored(&self) -> u64 {
        self.index.lock().unwrap().bytes
    }
}

impl Drop for DiskTier {
    fn drop(&mut self) {
        if let Some(dir) = &self.index.lock().unwrap().dir {
            let _ = std::fs::remove_dir_all(dir);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn key(p: u64) -> CacheKey {
        CacheKey { namespace: 7, generation: 0, partition: p, splits: 1 }
    }

    fn gkey(generation: u64, p: u64) -> CacheKey {
        CacheKey { namespace: 9, generation, partition: p, splits: 1 }
    }

    #[test]
    fn write_read_roundtrip() {
        let tier = DiskTier::new(None);
        assert!(tier.dir().is_none(), "directory is lazy");
        // Sequential bytes have no 4-byte repeats, so compression cannot
        // shrink the block and it stays codec-raw: stored == logical.
        let payload: Vec<u8> = (0..=255).collect();
        assert_eq!(tier.write(key(0), &payload).unwrap(), 256);
        assert!(tier.dir().is_some());
        assert_eq!(tier.read(&key(0)).unwrap().unwrap(), payload);
        assert_eq!(tier.len(), 1);
        assert_eq!(tier.bytes_stored(), 256);
        let s = tier.counters().snapshot();
        assert_eq!(s.disk_bytes_written, 256);
        assert_eq!(s.disk_bytes_read, 256);
        assert!(s.disk_write_secs >= 0.0 && s.disk_read_secs >= 0.0);
    }

    #[test]
    fn missing_block_reads_none() {
        let tier = DiskTier::new(None);
        assert!(tier.read(&key(9)).unwrap().is_none());
        assert!(tier.read_range(&key(9), 0, 10).unwrap().is_none());
        assert!(tier.meta(&key(9)).is_none());
        assert!(!tier.delete(&key(9)));
    }

    #[test]
    fn overwrite_replaces_bytes() {
        let tier = DiskTier::new(None);
        tier.write(key(1), &[0u8; 100]).unwrap();
        tier.write(key(1), &[1u8; 40]).unwrap();
        assert_eq!(tier.bytes_stored(), 40);
        assert_eq!(tier.read(&key(1)).unwrap().unwrap(), vec![1u8; 40]);
    }

    #[test]
    fn range_reads_stream_the_payload() {
        let tier = DiskTier::new(None);
        let payload: Vec<u8> = (0u8..100).collect();
        tier.write(key(2), &payload).unwrap();
        let mut got = Vec::new();
        let mut offset = 0u64;
        let mut sum = CHECKSUM_SEED;
        loop {
            let chunk = tier.read_range(&key(2), offset, 7).unwrap().unwrap();
            if chunk.is_empty() {
                break;
            }
            sum = checksum(sum, &chunk);
            offset += chunk.len() as u64;
            got.extend_from_slice(&chunk);
        }
        assert_eq!(got, payload);
        assert_eq!(sum, tier.meta(&key(2)).unwrap().checksum);
    }

    #[test]
    fn corruption_is_detected() {
        let tier = DiskTier::new(None);
        tier.write(key(3), b"precious bytes").unwrap();
        // Corrupt the payload on disk behind the tier's back.
        let path = tier.dir().unwrap().join(DiskTier::file_name(&key(3)));
        let mut bytes = std::fs::read(&path).unwrap();
        let last = bytes.len() - 1;
        bytes[last] ^= 0xFF;
        std::fs::write(&path, &bytes).unwrap();
        assert!(tier.read(&key(3)).is_err());
        assert_eq!(tier.counters().snapshot().checksum_failures, 1);
    }

    #[test]
    fn generation_cleanup_removes_old_blocks() {
        let tier = DiskTier::new(None);
        for generation in 0..3 {
            tier.write(gkey(generation, 0), &[generation as u8; 10]).unwrap();
            tier.write(gkey(generation, 1), &[generation as u8; 10]).unwrap();
        }
        tier.write(key(0), &[9u8; 10]).unwrap(); // other namespace: untouched
        assert_eq!(tier.delete_generations_below(9, 2), 4);
        assert_eq!(tier.len(), 3);
        assert_eq!(tier.bytes_stored(), 30);
        assert!(tier.meta(&gkey(2, 0)).is_some());
        assert!(tier.meta(&key(0)).is_some());
    }

    #[test]
    fn delete_frees_the_file() {
        let tier = DiskTier::new(None);
        tier.write(key(4), &[1u8; 8]).unwrap();
        let path = tier.dir().unwrap().join(DiskTier::file_name(&key(4)));
        assert!(path.exists());
        assert!(tier.delete(&key(4)));
        assert!(!path.exists());
        assert_eq!(tier.bytes_stored(), 0);
    }

    #[test]
    fn drop_removes_directory() {
        let dir;
        {
            let tier = DiskTier::new(None);
            tier.write(key(5), &[0u8; 4]).unwrap();
            dir = tier.dir().unwrap();
            assert!(dir.exists());
        }
        assert!(!dir.exists());
    }

    /// A repetitive multi-frame payload — the shape of a Zipf spill run.
    fn zipfish(len: usize) -> Vec<u8> {
        let mut data = Vec::with_capacity(len + 32);
        let words: [&[u8]; 6] = [b"the ", b"of ", b"and ", b"spark ", b"mpi ", b"wordcount "];
        let mut i = 0usize;
        while data.len() < len {
            data.extend_from_slice(words[[0, 0, 1, 0, 2, 3, 0, 4, 1, 5][i % 10]]);
            i += 1;
        }
        data.truncate(len);
        data
    }

    #[test]
    fn compressed_block_shrinks_on_disk() {
        let tier = DiskTier::new(None);
        let payload = zipfish(3 * RAW_FRAME + 1234); // four frames
        let logical = payload.len() as u64;
        assert_eq!(tier.write(key(6), &payload).unwrap(), logical, "write returns logical len");
        assert!(
            tier.bytes_stored() * 2 < logical,
            "expected >2x on-disk shrink, stored {} of {logical}",
            tier.bytes_stored()
        );
        assert_eq!(tier.read(&key(6)).unwrap().unwrap(), payload);
        let meta = tier.meta(&key(6)).unwrap();
        assert_eq!(meta.payload_len, logical, "meta stays logical");
        assert_eq!(meta.checksum, checksum(CHECKSUM_SEED, &payload));
        let s = tier.counters().snapshot();
        assert_eq!(s.disk_bytes_written, tier.bytes_stored(), "counters track stored bytes");
        assert_eq!(s.compress_raw_bytes, logical);
        assert_eq!(s.compress_stored_bytes, tier.bytes_stored());
        assert!(s.decompress_secs >= 0.0);
    }

    #[test]
    fn compressed_range_reads_match_logical_offsets() {
        let tier = DiskTier::new(None);
        let payload = zipfish(2 * RAW_FRAME + 999);
        tier.write(key(7), &payload).unwrap();
        // Stream the whole block in odd-sized chunks, verifying the
        // incremental checksum exactly like the spill cursor does.
        let mut got = Vec::new();
        let mut offset = 0u64;
        let mut sum = CHECKSUM_SEED;
        loop {
            let chunk = tier.read_range(&key(7), offset, 8192).unwrap().unwrap();
            if chunk.is_empty() {
                break;
            }
            sum = checksum(sum, &chunk);
            offset += chunk.len() as u64;
            got.extend_from_slice(&chunk);
        }
        assert_eq!(got, payload);
        assert_eq!(sum, tier.meta(&key(7)).unwrap().checksum);
        // A read straddling a frame boundary is capped at the frame end:
        // short but non-empty.
        let tail = tier.read_range(&key(7), RAW_FRAME as u64 - 10, 64).unwrap().unwrap();
        assert_eq!(tail.len(), 10);
        assert_eq!(tail[..], payload[RAW_FRAME - 10..RAW_FRAME]);
    }

    #[test]
    fn compression_off_stores_raw() {
        let tier = DiskTier::new(None).compression(false);
        let payload = zipfish(RAW_FRAME);
        tier.write(key(8), &payload).unwrap();
        assert_eq!(tier.bytes_stored(), payload.len() as u64);
        assert_eq!(tier.read(&key(8)).unwrap().unwrap(), payload);
        let s = tier.counters().snapshot();
        assert_eq!(s.compress_raw_bytes, 0, "no compression attempt when disabled");
        assert_eq!(s.disk_bytes_written, payload.len() as u64);
    }

    #[test]
    fn tiny_blocks_skip_compression() {
        let tier = DiskTier::new(None);
        tier.write(key(10), b"aaaaaaaaaaaa").unwrap();
        assert_eq!(tier.bytes_stored(), 12);
        assert_eq!(tier.counters().snapshot().compress_raw_bytes, 0);
    }

    #[test]
    fn corrupt_compressed_frame_is_detected() {
        let tier = DiskTier::new(None);
        let payload = zipfish(RAW_FRAME / 2);
        tier.write(key(11), &payload).unwrap();
        assert!(tier.bytes_stored() < payload.len() as u64, "block must actually compress");
        // Flip a byte inside the compressed frame body. Depending on
        // where it lands this either breaks the LZ4 stream (frame error)
        // or survives decode and trips the logical checksum — both must
        // surface as an error plus a counter tick, never a panic.
        let path = tier.dir().unwrap().join(DiskTier::file_name(&key(11)));
        let mut bytes = std::fs::read(&path).unwrap();
        let mid = (HEADER_LEN as usize + 12 + bytes.len()) / 2;
        bytes[mid] ^= 0xFF;
        std::fs::write(&path, &bytes).unwrap();
        assert!(tier.read(&key(11)).is_err());
        assert_eq!(tier.counters().snapshot().checksum_failures, 1);
    }

    #[test]
    fn overwrite_mixed_codecs_keeps_accounting() {
        let tier = DiskTier::new(None);
        let compressible = zipfish(RAW_FRAME);
        tier.write(key(12), &compressible).unwrap();
        let stored = tier.bytes_stored();
        assert!(stored < compressible.len() as u64);
        // Overwrite with a tiny raw block: accounting must subtract the
        // *stored* size of the old codec-1 block, not its logical size.
        tier.write(key(12), &[7u8; 20]).unwrap();
        assert_eq!(tier.bytes_stored(), 20);
        assert!(tier.delete(&key(12)));
        assert_eq!(tier.bytes_stored(), 0);
    }
}
