//! `DiskTier` — the disk tier of the storage hierarchy: checksummed
//! block files in a per-instance temp directory.
//!
//! One `DiskTier` serves one job (or one shared cache): spill runs,
//! demoted cache entries, and persisted shuffle blocks all write through
//! the same instance, so the job's disk traffic lands in one
//! [`StorageCounters`] cell (see the namespace map in the module docs).
//! The directory is created lazily on the first write — constructing a
//! tier costs nothing until something actually spills — and removed on
//! drop (generation-aware cleanup for long-lived tiers goes through
//! [`BlockStore::delete_generations_below`]).
//!
//! File layout: `[payload_len: u64 LE][fnv1a checksum: u64 LE][payload]`.
//! Full reads verify the checksum; range reads (the external-merge
//! cursors) accumulate it incrementally and verify at end-of-run against
//! [`BlockStore::meta`].

use std::collections::HashMap;
use std::io::{Read, Seek, SeekFrom, Write};
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering::Relaxed};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::cache::CacheKey;

use super::{checksum, BlockMeta, BlockStore, StorageCounters, CHECKSUM_SEED};

/// Bytes of on-disk header before the payload.
const HEADER_LEN: u64 = 16;

/// Process-wide uniquifier for tier directories (two tiers in one
/// process — a job's spill tier and a shared cache's — must not share a
/// directory even under the same base path).
static NEXT_DIR_ID: AtomicU64 = AtomicU64::new(0);

struct Index {
    blocks: HashMap<CacheKey, BlockMeta>,
    bytes: u64,
    /// Created lazily on first write; `None` until then.
    dir: Option<PathBuf>,
}

/// The disk tier (see module docs). Thread-safe; share as
/// `Arc<DiskTier>` (or `Arc<dyn BlockStore>`).
pub struct DiskTier {
    /// Base directory the tier's own subdirectory is created under
    /// (`None` = the system temp dir) — the `--spill-dir` knob.
    base: Option<PathBuf>,
    index: Mutex<Index>,
    counters: Arc<StorageCounters>,
}

impl std::fmt::Debug for DiskTier {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let index = self.index.lock().unwrap();
        f.debug_struct("DiskTier")
            .field("dir", &index.dir)
            .field("blocks", &index.blocks.len())
            .field("bytes", &index.bytes)
            .finish()
    }
}

impl DiskTier {
    /// A tier with its own fresh [`StorageCounters`] cell.
    pub fn new(base: Option<PathBuf>) -> Self {
        Self::with_counters(base, Arc::new(StorageCounters::default()))
    }

    /// A tier recording into an externally owned counters cell.
    pub fn with_counters(base: Option<PathBuf>, counters: Arc<StorageCounters>) -> Self {
        Self {
            base,
            index: Mutex::new(Index { blocks: HashMap::new(), bytes: 0, dir: None }),
            counters,
        }
    }

    /// The counters cell this tier (and its co-clients) record into.
    pub fn counters(&self) -> &Arc<StorageCounters> {
        &self.counters
    }

    /// The tier's directory, if anything was ever written.
    pub fn dir(&self) -> Option<PathBuf> {
        self.index.lock().unwrap().dir.clone()
    }

    fn file_name(key: &CacheKey) -> String {
        format!(
            "ns{:x}-g{}-p{:x}-s{}.blk",
            key.namespace, key.generation, key.partition, key.splits
        )
    }

    /// The directory, creating it on first use.
    fn ensure_dir(index: &mut Index, base: &Option<PathBuf>) -> std::io::Result<PathBuf> {
        if let Some(dir) = &index.dir {
            return Ok(dir.clone());
        }
        let parent = base.clone().unwrap_or_else(std::env::temp_dir);
        let dir = parent.join(format!(
            "blaze-tier-{}-{}",
            std::process::id(),
            NEXT_DIR_ID.fetch_add(1, Relaxed)
        ));
        std::fs::create_dir_all(&dir)?;
        index.dir = Some(dir.clone());
        Ok(dir)
    }

    fn remove_file(index: &Index, key: &CacheKey) {
        if let Some(dir) = &index.dir {
            let _ = std::fs::remove_file(dir.join(Self::file_name(key)));
        }
    }

    /// Drop every block in the tier (counters are kept). Only safe for
    /// tiers with a single client — callers sharing a tier retire their
    /// own keys via [`BlockStore::delete`] /
    /// [`BlockStore::delete_generations_below`] instead.
    pub fn clear_all(&self) {
        let mut index = self.index.lock().unwrap();
        let victims: Vec<CacheKey> = index.blocks.keys().copied().collect();
        for key in &victims {
            index.blocks.remove(key);
            Self::remove_file(&index, key);
        }
        index.bytes = 0;
    }
}

impl BlockStore for DiskTier {
    fn write(&self, key: CacheKey, payload: &[u8]) -> std::io::Result<u64> {
        let t0 = Instant::now();
        let meta = BlockMeta {
            payload_len: payload.len() as u64,
            checksum: checksum(CHECKSUM_SEED, payload),
        };
        let path = {
            let mut index = self.index.lock().unwrap();
            let dir = Self::ensure_dir(&mut index, &self.base)?;
            dir.join(Self::file_name(&key))
        };
        let mut f = std::fs::File::create(&path)?;
        f.write_all(&meta.payload_len.to_le_bytes())?;
        f.write_all(&meta.checksum.to_le_bytes())?;
        f.write_all(payload)?;
        f.flush()?;
        {
            let mut index = self.index.lock().unwrap();
            if let Some(old) = index.blocks.insert(key, meta) {
                index.bytes -= old.payload_len;
            }
            index.bytes += meta.payload_len;
        }
        self.counters.record_disk_write(payload.len() as u64, t0.elapsed());
        Ok(meta.payload_len)
    }

    fn read(&self, key: &CacheKey) -> std::io::Result<Option<Vec<u8>>> {
        let t0 = Instant::now();
        let (path, meta) = {
            let index = self.index.lock().unwrap();
            let Some(meta) = index.blocks.get(key).copied() else {
                return Ok(None);
            };
            let dir = index.dir.clone().expect("indexed block without a tier dir");
            (dir.join(Self::file_name(key)), meta)
        };
        let mut f = std::fs::File::open(&path)?;
        let mut header = [0u8; HEADER_LEN as usize];
        f.read_exact(&mut header)?;
        let stored_len = u64::from_le_bytes(header[0..8].try_into().unwrap());
        let stored_sum = u64::from_le_bytes(header[8..16].try_into().unwrap());
        // Validate the (untrusted) on-disk header against the trusted
        // in-memory index *before* sizing any allocation from it — a
        // corrupt length must surface as the graceful InvalidData error,
        // not an OOM.
        if stored_len != meta.payload_len || stored_sum != meta.checksum {
            self.counters.record_checksum_failure();
            return Err(std::io::Error::new(
                std::io::ErrorKind::InvalidData,
                format!("block {key:?} has a corrupt header"),
            ));
        }
        let mut payload = Vec::with_capacity(meta.payload_len as usize);
        f.read_to_end(&mut payload)?;
        let ok = payload.len() as u64 == meta.payload_len
            && checksum(CHECKSUM_SEED, &payload) == meta.checksum;
        if !ok {
            self.counters.record_checksum_failure();
            return Err(std::io::Error::new(
                std::io::ErrorKind::InvalidData,
                format!("block {key:?} failed checksum verification"),
            ));
        }
        self.counters.record_disk_read(payload.len() as u64, t0.elapsed());
        Ok(Some(payload))
    }

    fn read_range(
        &self,
        key: &CacheKey,
        offset: u64,
        max_len: usize,
    ) -> std::io::Result<Option<Vec<u8>>> {
        let t0 = Instant::now();
        let (path, meta) = {
            let index = self.index.lock().unwrap();
            let Some(meta) = index.blocks.get(key).copied() else {
                return Ok(None);
            };
            let dir = index.dir.clone().expect("indexed block without a tier dir");
            (dir.join(Self::file_name(key)), meta)
        };
        if offset >= meta.payload_len {
            return Ok(Some(Vec::new()));
        }
        let want = max_len.min((meta.payload_len - offset) as usize);
        let mut f = std::fs::File::open(&path)?;
        f.seek(SeekFrom::Start(HEADER_LEN + offset))?;
        let mut buf = vec![0u8; want];
        f.read_exact(&mut buf)?;
        self.counters.record_disk_read(want as u64, t0.elapsed());
        Ok(Some(buf))
    }

    fn meta(&self, key: &CacheKey) -> Option<BlockMeta> {
        self.index.lock().unwrap().blocks.get(key).copied()
    }

    fn delete(&self, key: &CacheKey) -> bool {
        let mut index = self.index.lock().unwrap();
        match index.blocks.remove(key) {
            Some(meta) => {
                index.bytes -= meta.payload_len;
                Self::remove_file(&index, key);
                true
            }
            None => false,
        }
    }

    fn delete_generations_below(&self, namespace: u64, keep_generation: u64) -> usize {
        let mut index = self.index.lock().unwrap();
        let victims: Vec<CacheKey> = index
            .blocks
            .keys()
            .filter(|k| k.namespace == namespace && k.generation < keep_generation)
            .copied()
            .collect();
        for key in &victims {
            let meta = index.blocks.remove(key).unwrap();
            index.bytes -= meta.payload_len;
            Self::remove_file(&index, key);
        }
        victims.len()
    }

    fn len(&self) -> usize {
        self.index.lock().unwrap().blocks.len()
    }

    fn bytes_stored(&self) -> u64 {
        self.index.lock().unwrap().bytes
    }
}

impl Drop for DiskTier {
    fn drop(&mut self) {
        if let Some(dir) = &self.index.lock().unwrap().dir {
            let _ = std::fs::remove_dir_all(dir);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn key(p: u64) -> CacheKey {
        CacheKey { namespace: 7, generation: 0, partition: p, splits: 1 }
    }

    fn gkey(generation: u64, p: u64) -> CacheKey {
        CacheKey { namespace: 9, generation, partition: p, splits: 1 }
    }

    #[test]
    fn write_read_roundtrip() {
        let tier = DiskTier::new(None);
        assert!(tier.dir().is_none(), "directory is lazy");
        let payload: Vec<u8> = (0..=255).collect();
        assert_eq!(tier.write(key(0), &payload).unwrap(), 256);
        assert!(tier.dir().is_some());
        assert_eq!(tier.read(&key(0)).unwrap().unwrap(), payload);
        assert_eq!(tier.len(), 1);
        assert_eq!(tier.bytes_stored(), 256);
        let s = tier.counters().snapshot();
        assert_eq!(s.disk_bytes_written, 256);
        assert_eq!(s.disk_bytes_read, 256);
        assert!(s.disk_write_secs >= 0.0 && s.disk_read_secs >= 0.0);
    }

    #[test]
    fn missing_block_reads_none() {
        let tier = DiskTier::new(None);
        assert!(tier.read(&key(9)).unwrap().is_none());
        assert!(tier.read_range(&key(9), 0, 10).unwrap().is_none());
        assert!(tier.meta(&key(9)).is_none());
        assert!(!tier.delete(&key(9)));
    }

    #[test]
    fn overwrite_replaces_bytes() {
        let tier = DiskTier::new(None);
        tier.write(key(1), &[0u8; 100]).unwrap();
        tier.write(key(1), &[1u8; 40]).unwrap();
        assert_eq!(tier.bytes_stored(), 40);
        assert_eq!(tier.read(&key(1)).unwrap().unwrap(), vec![1u8; 40]);
    }

    #[test]
    fn range_reads_stream_the_payload() {
        let tier = DiskTier::new(None);
        let payload: Vec<u8> = (0u8..100).collect();
        tier.write(key(2), &payload).unwrap();
        let mut got = Vec::new();
        let mut offset = 0u64;
        let mut sum = CHECKSUM_SEED;
        loop {
            let chunk = tier.read_range(&key(2), offset, 7).unwrap().unwrap();
            if chunk.is_empty() {
                break;
            }
            sum = checksum(sum, &chunk);
            offset += chunk.len() as u64;
            got.extend_from_slice(&chunk);
        }
        assert_eq!(got, payload);
        assert_eq!(sum, tier.meta(&key(2)).unwrap().checksum);
    }

    #[test]
    fn corruption_is_detected() {
        let tier = DiskTier::new(None);
        tier.write(key(3), b"precious bytes").unwrap();
        // Corrupt the payload on disk behind the tier's back.
        let path = tier.dir().unwrap().join(DiskTier::file_name(&key(3)));
        let mut bytes = std::fs::read(&path).unwrap();
        let last = bytes.len() - 1;
        bytes[last] ^= 0xFF;
        std::fs::write(&path, &bytes).unwrap();
        assert!(tier.read(&key(3)).is_err());
        assert_eq!(tier.counters().snapshot().checksum_failures, 1);
    }

    #[test]
    fn generation_cleanup_removes_old_blocks() {
        let tier = DiskTier::new(None);
        for generation in 0..3 {
            tier.write(gkey(generation, 0), &[generation as u8; 10]).unwrap();
            tier.write(gkey(generation, 1), &[generation as u8; 10]).unwrap();
        }
        tier.write(key(0), &[9u8; 10]).unwrap(); // other namespace: untouched
        assert_eq!(tier.delete_generations_below(9, 2), 4);
        assert_eq!(tier.len(), 3);
        assert_eq!(tier.bytes_stored(), 30);
        assert!(tier.meta(&gkey(2, 0)).is_some());
        assert!(tier.meta(&key(0)).is_some());
    }

    #[test]
    fn delete_frees_the_file() {
        let tier = DiskTier::new(None);
        tier.write(key(4), &[1u8; 8]).unwrap();
        let path = tier.dir().unwrap().join(DiskTier::file_name(&key(4)));
        assert!(path.exists());
        assert!(tier.delete(&key(4)));
        assert!(!path.exists());
        assert_eq!(tier.bytes_stored(), 0);
    }

    #[test]
    fn drop_removes_directory() {
        let dir;
        {
            let tier = DiskTier::new(None);
            tier.write(key(5), &[0u8; 4]).unwrap();
            dir = tier.dir().unwrap();
            assert!(dir.exists());
        }
        assert!(!dir.exists());
    }
}
