//! The trace lab: record real [`CacheKey`] access traces from live runs
//! and replay them through any eviction policy.
//!
//! Hit-rate claims about eviction policies are easy to hand-wave and hard
//! to falsify — unless the exact access sequence a workload generates can
//! be captured and re-driven through every policy under identical
//! conditions. That is what this module does:
//!
//! * [`TraceRecorder`] — attach one to a [`TieredStore`](super::TieredStore)
//!   (see [`attach_recorder`](super::TieredStore::attach_recorder)) and it
//!   logs every `get`/`put` crossing the store's public surface as a
//!   [`TraceEvent`] (op, key, size estimate). Tier-internal movement
//!   (demotion, promotion) is *not* recorded: it is a consequence of the
//!   policy under trial, and replay regenerates it.
//! * [`replay`] — drive a recorded trace through a fresh
//!   [`MemoryTier`](super::MemoryTier) under any [`PolicySpec`] and report
//!   the resulting [`CacheStats`]. Replay uses the real tier (real
//!   admission, real victim selection, real stats), with unit values in
//!   place of payloads — so hit-rates are exact, not modeled.
//!
//! Traces serialize to a compact binary log ([`TraceRecorder::to_bytes`] /
//! [`TraceRecorder::events_from_bytes`], 33 bytes per event) so benches
//! can persist them next to their `BENCH_*.json` artifacts. Everything is
//! deterministic: the same trace replayed twice under the same policy
//! yields identical stats (CI asserts this).

use std::sync::atomic::{AtomicU64, Ordering::Relaxed};
use std::sync::{Arc, Mutex};

use crate::cache::{CacheBudget, CacheKey, CacheStats};
use crate::util::ser::{Decode, DecodeError, Encode, Reader};

use super::policy::PolicySpec;
use super::MemoryTier;

/// What crossed the store's surface.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TraceOp {
    /// Any lookup (`get` / `get_typed` / `get_encoded`).
    Get,
    /// Any insert (`put` / `put_encoded`), with its heap estimate.
    Put,
}

/// One recorded store access.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TraceEvent {
    pub op: TraceOp,
    pub key: CacheKey,
    /// Heap estimate for `Put`; 0 for `Get`.
    pub bytes: u64,
}

impl Encode for TraceEvent {
    fn encode(&self, out: &mut Vec<u8>) {
        out.push(match self.op {
            TraceOp::Get => 0,
            TraceOp::Put => 1,
        });
        self.key.namespace.encode(out);
        self.key.generation.encode(out);
        self.key.partition.encode(out);
        self.key.splits.encode(out);
        self.bytes.encode(out);
    }
}

impl Decode for TraceEvent {
    fn decode(r: &mut Reader<'_>) -> Result<Self, DecodeError> {
        let op = match u8::decode(r)? {
            0 => TraceOp::Get,
            1 => TraceOp::Put,
            t => return Err(DecodeError::BadTag(t)),
        };
        let key = CacheKey {
            namespace: u64::decode(r)?,
            generation: u64::decode(r)?,
            partition: u64::decode(r)?,
            splits: u64::decode(r)?,
        };
        Ok(TraceEvent { op, key, bytes: u64::decode(r)? })
    }
}

/// Thread-safe access-trace sink (stores share one across workers).
#[derive(Debug, Default)]
pub struct TraceRecorder {
    events: Mutex<Vec<TraceEvent>>,
    /// Bytes put, cumulative — sizes the replay budget sweep cheaply.
    put_bytes: AtomicU64,
}

impl TraceRecorder {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(&self, op: TraceOp, key: CacheKey, bytes: u64) {
        if let TraceOp::Put = op {
            self.put_bytes.fetch_add(bytes, Relaxed);
        }
        self.events.lock().unwrap().push(TraceEvent { op, key, bytes });
    }

    /// Snapshot of the recorded events, in arrival order.
    pub fn events(&self) -> Vec<TraceEvent> {
        self.events.lock().unwrap().clone()
    }

    pub fn len(&self) -> usize {
        self.events.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Total bytes across all recorded `Put`s (an upper bound on the
    /// working set — useful for picking replay budgets).
    pub fn put_bytes(&self) -> u64 {
        self.put_bytes.load(Relaxed)
    }

    pub fn clear(&self) {
        self.events.lock().unwrap().clear();
        self.put_bytes.store(0, Relaxed);
    }

    /// The compact binary log: `u64` count, then 33 bytes per event.
    pub fn to_bytes(&self) -> Vec<u8> {
        let events = self.events.lock().unwrap();
        let mut out = Vec::with_capacity(8 + events.len() * 33);
        (events.len() as u64).encode(&mut out);
        for e in events.iter() {
            e.encode(&mut out);
        }
        out
    }

    /// Decode a log written by [`Self::to_bytes`].
    pub fn events_from_bytes(bytes: &[u8]) -> Result<Vec<TraceEvent>, DecodeError> {
        let mut r = Reader::new(bytes);
        let n = u64::decode(&mut r)? as usize;
        let mut events = Vec::with_capacity(n.min(1 << 20));
        for _ in 0..n {
            events.push(TraceEvent::decode(&mut r)?);
        }
        if !r.is_empty() {
            return Err(DecodeError::TrailingBytes(r.remaining()));
        }
        Ok(events)
    }
}

/// Replay a trace through a fresh memory tier under `spec` at `budget`,
/// returning the tier's final stats. Deterministic: identical inputs give
/// identical stats.
pub fn replay(events: &[TraceEvent], budget: CacheBudget, spec: PolicySpec) -> CacheStats {
    let tier = MemoryTier::with_policy(budget, spec);
    for e in events {
        match e.op {
            TraceOp::Get => {
                tier.get(&e.key);
            }
            TraceOp::Put => {
                tier.put(e.key, Arc::new(()), e.bytes, None);
            }
        }
    }
    tier.stats()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn key(p: u64) -> CacheKey {
        CacheKey { namespace: 0, generation: 0, partition: p, splits: 1 }
    }

    #[test]
    fn log_round_trips() {
        let rec = TraceRecorder::new();
        rec.record(TraceOp::Put, key(1), 100);
        rec.record(TraceOp::Get, key(1), 0);
        rec.record(TraceOp::Get, key(2), 0);
        assert_eq!(rec.len(), 3);
        assert_eq!(rec.put_bytes(), 100);
        let back = TraceRecorder::events_from_bytes(&rec.to_bytes()).unwrap();
        assert_eq!(back, rec.events());
        assert!(TraceRecorder::events_from_bytes(&[1, 2, 3]).is_err());
    }

    #[test]
    fn replay_reproduces_live_stats() {
        // A live store and a replay of its trace must agree exactly.
        let store = super::super::TieredStore::new(CacheBudget::Bytes(64));
        let rec = Arc::new(TraceRecorder::new());
        store.attach_recorder(Arc::clone(&rec));
        for round in 0..3 {
            for p in 0..4u64 {
                if store.get(&key(p)).is_none() {
                    store.put(key(p), Arc::new(round), 20);
                }
            }
        }
        let live = store.stats();
        let replayed = replay(&rec.events(), CacheBudget::Bytes(64), PolicySpec::LRU);
        assert_eq!((replayed.hits, replayed.misses), (live.hits, live.misses));
        assert_eq!(replayed.evictions, live.evictions);
        // And replay is deterministic.
        let again = replay(&rec.events(), CacheBudget::Bytes(64), PolicySpec::LRU);
        assert_eq!(replayed, again);
    }

    #[test]
    fn replay_honors_the_policy() {
        // Hot small keys interleaved with a cold scan: every policy must
        // replay the same lookup count and keep the budget invariant.
        let mut events = Vec::new();
        for round in 0..20 {
            for p in 0..2u64 {
                events.push(TraceEvent { op: TraceOp::Get, key: key(p), bytes: 0 });
                events.push(TraceEvent { op: TraceOp::Put, key: key(p), bytes: 10 });
            }
            events.push(TraceEvent { op: TraceOp::Put, key: key(100 + round), bytes: 25 });
        }
        for spec in PolicySpec::all() {
            let stats = replay(&events, CacheBudget::Bytes(50), spec);
            assert_eq!(stats.hits + stats.misses, 40, "{spec}");
            assert!(stats.bytes_cached <= 50, "{spec}");
        }
    }
}
