//! `MemoryTier` — the memory tier of the storage hierarchy.
//!
//! This is the PR 3 partition-cache mechanism (type-erased values, byte
//! budget, eviction, hit/miss/evict/reject stats — see [`crate::cache`]
//! for the `spark.memory.fraction` mapping) factored into a tier: instead
//! of silently dropping evicted entries, `put` returns the victims, and
//! victims that carry an [`EncodeFn`] can be **demoted** to the tier
//! below by the caller ([`super::TieredStore`] does exactly that). The
//! tier itself never touches disk.
//!
//! *Which* entry is evicted — and, under an admission filter, whether a
//! newcomer is stored at all — is decided by a pluggable
//! [`EvictionPolicy`] (see [`super::policy`]); [`MemoryTier::new`] keeps
//! the PR 3 LRU behavior, [`MemoryTier::with_policy`] picks any
//! [`PolicySpec`]. The tier owns the slots and the byte accounting and
//! mirrors every residency change into the policy, so the two can never
//! disagree about what is resident.

use std::any::Any;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering::Relaxed};
use std::sync::{Arc, Mutex};

use crate::cache::{CacheBudget, CacheKey, CacheStats};

use super::policy::{EvictionPolicy, PolicySpec};

/// Serializer attached to a demotable entry: produces the wire form of
/// the stored value (captured over the typed `Arc` at insert time, so no
/// downcasting is needed at eviction time).
pub type EncodeFn = Arc<dyn Fn() -> Vec<u8> + Send + Sync>;

/// An entry evicted under budget pressure. `encode` is `Some` when the
/// writer registered a serializer — the caller may demote it to a lower
/// tier; `None` entries are simply gone (the PR 3 behavior).
pub struct Victim {
    pub key: CacheKey,
    /// The writer's heap-size estimate for the entry.
    pub bytes: u64,
    pub encode: Option<EncodeFn>,
}

/// One resident value: type-erased payload + size + optional serializer
/// (recency/frequency metadata lives in the policy).
struct Slot {
    value: Arc<dyn Any + Send + Sync>,
    bytes: u64,
    encode: Option<EncodeFn>,
}

struct Inner {
    slots: HashMap<CacheKey, Slot>,
    bytes: u64,
    policy: Box<dyn EvictionPolicy>,
}

/// The memory-budgeted, size-aware memory tier (see module docs).
/// Thread-safe and cheap to share.
pub struct MemoryTier {
    budget: CacheBudget,
    spec: PolicySpec,
    inner: Mutex<Inner>,
    hits: AtomicU64,
    misses: AtomicU64,
    insertions: AtomicU64,
    evictions: AtomicU64,
    rejected: AtomicU64,
}

impl std::fmt::Debug for MemoryTier {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("MemoryTier")
            .field("budget", &self.budget)
            .field("policy", &self.spec)
            .field("stats", &self.stats())
            .finish()
    }
}

impl MemoryTier {
    /// LRU tier — the PR 3 behavior, verbatim.
    pub fn new(budget: CacheBudget) -> Self {
        Self::with_policy(budget, PolicySpec::default())
    }

    /// A tier evicting (and admitting) per `spec`.
    pub fn with_policy(budget: CacheBudget, spec: PolicySpec) -> Self {
        Self {
            budget,
            spec,
            inner: Mutex::new(Inner {
                slots: HashMap::new(),
                bytes: 0,
                policy: spec.build(budget),
            }),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            insertions: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
            rejected: AtomicU64::new(0),
        }
    }

    pub fn budget(&self) -> CacheBudget {
        self.budget
    }

    /// The eviction policy this tier was built with.
    pub fn policy(&self) -> PolicySpec {
        self.spec
    }

    /// `true` when the budget is `Bytes(0)`: nothing can ever be admitted.
    pub fn is_disabled(&self) -> bool {
        self.budget == CacheBudget::Bytes(0)
    }

    /// Could an entry of `bytes` estimated size ever be admitted to
    /// *this* tier? (`false` = a `put` is guaranteed to reject it; `true`
    /// does not preclude an admission-filter rejection.)
    pub fn fits(&self, bytes: u64) -> bool {
        match self.budget {
            CacheBudget::Unbounded => true,
            CacheBudget::Bytes(limit) => limit > 0 && bytes <= limit,
        }
    }

    /// Look up an entry. A hit bumps its recency and is counted.
    pub fn get(&self, key: &CacheKey) -> Option<Arc<dyn Any + Send + Sync>> {
        let mut span = crate::trace::span(crate::trace::SpanCat::CacheLookup, "get");
        let mut inner = self.inner.lock().unwrap();
        let value = inner.slots.get(key).map(|slot| Arc::clone(&slot.value));
        match value {
            Some(v) => {
                span.set_arg(1); // hit
                inner.policy.on_hit(key);
                self.hits.fetch_add(1, Relaxed);
                Some(v)
            }
            None => {
                inner.policy.on_miss(key);
                self.misses.fetch_add(1, Relaxed);
                None
            }
        }
    }

    /// Insert an entry of `bytes` estimated size, evicting the policy's
    /// victims until it fits. Returns `(admitted, victims)`: rejected
    /// inserts (entry alone over the whole budget; any entry at budget 0;
    /// a newcomer refused by the policy's admission filter) count a
    /// rejection and produce no victims. Victims are counted as evictions
    /// whether or not the caller demotes them. Overwrites of resident
    /// keys bypass the admission filter — the entry already earned its
    /// place.
    pub fn put(
        &self,
        key: CacheKey,
        value: Arc<dyn Any + Send + Sync>,
        bytes: u64,
        encode: Option<EncodeFn>,
    ) -> (bool, Vec<Victim>) {
        if let CacheBudget::Bytes(limit) = self.budget {
            if limit == 0 || bytes > limit {
                self.rejected.fetch_add(1, Relaxed);
                return (false, Vec::new());
            }
        }
        let mut inner = self.inner.lock().unwrap();
        let overwrite = inner.slots.contains_key(&key);
        if let Some(old) = inner.slots.remove(&key) {
            inner.bytes -= old.bytes;
            inner.policy.forget(&key);
        }
        let need = match self.budget {
            CacheBudget::Unbounded => 0,
            CacheBudget::Bytes(limit) => (inner.bytes + bytes).saturating_sub(limit),
        };
        let victim_keys = inner.policy.victims(need);
        if !overwrite && !inner.policy.admits(&key, bytes, &victim_keys) {
            self.rejected.fetch_add(1, Relaxed);
            return (false, Vec::new());
        }
        let mut victims = Vec::with_capacity(victim_keys.len());
        for vk in victim_keys {
            let slot = inner.slots.remove(&vk).expect("policy victim must be resident");
            inner.bytes -= slot.bytes;
            inner.policy.on_evict(&vk);
            self.evictions.fetch_add(1, Relaxed);
            victims.push(Victim { key: vk, bytes: slot.bytes, encode: slot.encode });
        }
        if let CacheBudget::Bytes(limit) = self.budget {
            debug_assert!(
                inner.bytes + bytes <= limit,
                "policy victims must cover the shortfall"
            );
        }
        inner.policy.record_insert(key, bytes);
        inner.bytes += bytes;
        inner.slots.insert(key, Slot { value, bytes, encode });
        self.insertions.fetch_add(1, Relaxed);
        crate::trace::counter("cache bytes", inner.bytes);
        (true, victims)
    }

    /// Is `key` currently resident? Does not touch recency or stats.
    pub fn contains(&self, key: &CacheKey) -> bool {
        self.inner.lock().unwrap().slots.contains_key(key)
    }

    /// Remove one entry without counting an eviction (deliberate removal,
    /// not budget pressure). Returns whether it existed.
    pub fn remove(&self, key: &CacheKey) -> bool {
        let mut inner = self.inner.lock().unwrap();
        match inner.slots.remove(key) {
            Some(slot) => {
                inner.bytes -= slot.bytes;
                inner.policy.forget(key);
                true
            }
            None => false,
        }
    }

    /// Drop every resident entry of `namespace` older than
    /// `keep_generation`. Not counted as evictions. Returns the count.
    pub fn invalidate_generations_below(&self, namespace: u64, keep_generation: u64) -> usize {
        let mut inner = self.inner.lock().unwrap();
        let victims: Vec<CacheKey> = inner
            .slots
            .keys()
            .filter(|k| k.namespace == namespace && k.generation < keep_generation)
            .copied()
            .collect();
        for k in &victims {
            let slot = inner.slots.remove(k).unwrap();
            inner.bytes -= slot.bytes;
            inner.policy.forget(k);
        }
        victims.len()
    }

    /// Estimated bytes currently resident.
    pub fn bytes_cached(&self) -> u64 {
        self.inner.lock().unwrap().bytes
    }

    /// Estimated bytes resident across every namespace in `[lo, hi)` —
    /// the per-tenant accounting behind [`super::TieredStore`] namespace
    /// quotas (a tenant's datasets live in one contiguous namespace
    /// range). Linear in the number of resident entries.
    pub fn bytes_in_namespace_range(&self, lo: u64, hi: u64) -> u64 {
        let inner = self.inner.lock().unwrap();
        inner
            .slots
            .iter()
            .filter(|(k, _)| k.namespace >= lo && k.namespace < hi)
            .map(|(_, slot)| slot.bytes)
            .sum()
    }

    /// The size estimate a resident entry was admitted under (`None`
    /// when absent). Does not touch recency or stats.
    pub fn entry_bytes(&self, key: &CacheKey) -> Option<u64> {
        self.inner.lock().unwrap().slots.get(key).map(|slot| slot.bytes)
    }

    /// Count a rejection decided by a wrapper above this tier (the
    /// tiered store's namespace quotas refuse entries before they reach
    /// [`put`](Self::put), but the refusal belongs in these stats).
    pub(crate) fn count_rejection(&self) {
        self.rejected.fetch_add(1, Relaxed);
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().slots.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Drop every entry (counters are kept — they are cumulative; the
    /// policy may keep learned history such as frequency sketches).
    pub fn clear(&self) {
        let mut inner = self.inner.lock().unwrap();
        inner.slots.clear();
        inner.bytes = 0;
        inner.policy.reset();
    }

    /// Reclassify one counted miss as a hit — the tiered store calls this
    /// when a memory miss is served from the tier below (the lookup *was*
    /// a storage hit, just not a memory one).
    pub(crate) fn reclassify_miss_as_hit(&self) {
        self.misses.fetch_sub(1, Relaxed);
        self.hits.fetch_add(1, Relaxed);
    }

    /// Reclassify one counted hit as a miss (a typed lookup that
    /// downcast-failed: the caller will recompute).
    pub(crate) fn reclassify_hit_as_miss(&self) {
        self.hits.fetch_sub(1, Relaxed);
        self.misses.fetch_add(1, Relaxed);
    }

    pub fn stats(&self) -> CacheStats {
        let (bytes_cached, entries) = {
            let inner = self.inner.lock().unwrap();
            (inner.bytes, inner.slots.len() as u64)
        };
        CacheStats {
            hits: self.hits.load(Relaxed),
            misses: self.misses.load(Relaxed),
            insertions: self.insertions.load(Relaxed),
            evictions: self.evictions.load(Relaxed),
            rejected: self.rejected.load(Relaxed),
            bytes_cached,
            entries,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn key(p: u64) -> CacheKey {
        CacheKey { namespace: 0, generation: 0, partition: p, splits: 1 }
    }

    fn val(x: u64) -> Arc<dyn Any + Send + Sync> {
        Arc::new(vec![x])
    }

    #[test]
    fn eviction_hands_back_demotable_victims() {
        let tier = MemoryTier::new(CacheBudget::Bytes(100));
        let payload = Arc::new(vec![1u64, 2]);
        let enc: EncodeFn = {
            let p = Arc::clone(&payload);
            Arc::new(move || {
                crate::util::ser::Encode::to_bytes(p.as_ref())
            })
        };
        let (ok, victims) = tier.put(key(1), payload, 80, Some(enc));
        assert!(ok && victims.is_empty());
        // Inserting a second entry forces the first out — with its encoder.
        let (ok, victims) = tier.put(key(2), val(9), 60, None);
        assert!(ok);
        assert_eq!(victims.len(), 1);
        assert_eq!(victims[0].key, key(1));
        assert_eq!(victims[0].bytes, 80);
        let bytes = victims[0].encode.as_ref().expect("demotable")();
        let back: Vec<u64> = crate::util::ser::Decode::from_bytes(&bytes).unwrap();
        assert_eq!(back, vec![1, 2]);
        assert_eq!(tier.stats().evictions, 1);
    }

    #[test]
    fn plain_victims_have_no_encoder() {
        let tier = MemoryTier::new(CacheBudget::Bytes(50));
        tier.put(key(1), val(1), 40, None);
        let (_, victims) = tier.put(key(2), val(2), 40, None);
        assert_eq!(victims.len(), 1);
        assert!(victims[0].encode.is_none());
    }

    #[test]
    fn remove_is_not_an_eviction() {
        let tier = MemoryTier::new(CacheBudget::Unbounded);
        tier.put(key(1), val(1), 10, None);
        assert!(tier.remove(&key(1)));
        assert!(!tier.remove(&key(1)));
        assert_eq!(tier.bytes_cached(), 0);
        assert_eq!(tier.stats().evictions, 0);
    }

    #[test]
    fn default_policy_is_lru() {
        assert_eq!(MemoryTier::new(CacheBudget::Unbounded).policy(), PolicySpec::LRU);
    }

    #[test]
    fn admission_filter_rejection_counts_as_rejected() {
        let tier = MemoryTier::with_policy(CacheBudget::Bytes(100), PolicySpec::TINYLFU);
        tier.put(key(1), val(1), 100, None);
        for _ in 0..5 {
            tier.get(&key(1));
        }
        // A cold newcomer that would evict the hot entry is refused.
        let (ok, victims) = tier.put(key(2), val(2), 100, None);
        assert!(!ok && victims.is_empty());
        assert!(tier.contains(&key(1)));
        let s = tier.stats();
        assert_eq!((s.rejected, s.evictions, s.insertions), (1, 0, 1));
    }

    #[test]
    fn overwrites_bypass_the_admission_filter() {
        let tier = MemoryTier::with_policy(CacheBudget::Bytes(100), PolicySpec::TINYLFU);
        tier.put(key(1), val(1), 100, None);
        // Overwriting a resident key must never lose the entry.
        let (ok, _) = tier.put(key(1), val(9), 100, None);
        assert!(ok);
        assert_eq!(tier.len(), 1);
    }

    #[test]
    fn every_policy_keeps_the_budget_invariant() {
        for spec in PolicySpec::all() {
            let tier = MemoryTier::with_policy(CacheBudget::Bytes(100), spec);
            for p in 0..50 {
                tier.put(key(p), val(p), 7 + p % 13, None);
                tier.get(&key(p / 2));
                assert!(tier.bytes_cached() <= 100, "{spec}");
            }
        }
    }
}
