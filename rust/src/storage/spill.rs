//! The bounded-memory exchange: sort-and-spill accumulation plus a
//! loser-tree external merge.
//!
//! [`ExternalMerger`] is the reduce-side accumulator both engines use
//! when a shuffle partition's in-flight bytes may exceed the memory
//! budget (Spark's `ExternalAppendOnlyMap` role):
//!
//! * [`insert`](ExternalMerger::insert) combines into an in-memory map,
//!   tracking estimated heap bytes ([`HeapSize`]); crossing the budget
//!   **sorts the resident entries by key and spills them as one run** to
//!   the block store (keys dictionary-encoded per run via
//!   [`DictWriter`], values in the crate wire format, checksummed and
//!   transparently block-compressed by the
//!   [`DiskTier`](super::DiskTier));
//! * [`finish`](ExternalMerger::finish) merges every spilled run plus
//!   the in-memory remainder with a **loser tree** ([`LoserTree`]) —
//!   runs are streamed back in bounded chunks
//!   ([`BlockStore::read_range`]), decoded **zero-copy** into per-run
//!   arena handles ([`DataKey::Ref`], 8 bytes for string keys) so the
//!   merge compares and folds without allocating a `String` per record;
//!   equal keys across runs are folded with the combiner, and the
//!   result is bit-identical to the all-in-memory fold for any
//!   associative + commutative combine, at any budget down to zero
//!   (budget 0 spills every insert).
//!
//! A spill **write failure is not data loss**: the entries stay in
//! memory, the failure is counted, and the effective budget doubles so
//! the merger makes progress instead of hot-looping on a dead disk —
//! the property suite injects exactly this.

use std::collections::HashMap;
use std::hash::Hash;
use std::marker::PhantomData;
use std::sync::Arc;

use crate::cache::CacheKey;
use crate::util::ser::{DataKey, Decode, DecodeError, DictReader, DictWriter, Encode, Reader};

use super::{checksum, BlockStore, HeapSize, StorageCounters, CHECKSUM_SEED};

/// Bytes fetched per [`BlockStore::read_range`] call while streaming a
/// run back during the merge — the merge phase holds one chunk per open
/// run, not whole runs.
const RUN_READ_CHUNK: usize = 64 << 10;

/// Estimated header cost of one `(K, V)` entry in the accumulator.
const PAIR_OVERHEAD: u64 = 16;

/// Inserts between the first exact re-estimations of the resident set
/// (the interval doubles after each sample — Spark's `SizeTracker`
/// idea). Between samples every combining insert charges the *incoming*
/// value's estimate, which only ever over-counts, so the budget can
/// never be silently exceeded; the walk over the accumulated values —
/// `O(resident)` — happens `O(log inserts)` times instead of twice per
/// insert.
const SAMPLE_BASE: u64 = 64;

/// The spilling accumulator (see module docs).
pub struct ExternalMerger<K, V> {
    mem: HashMap<K, V>,
    mem_bytes: u64,
    /// The configured budget.
    threshold: u64,
    /// The budget currently enforced (raised temporarily after a failed
    /// spill so the merger keeps making progress).
    limit: u64,
    /// Exact-size resampling schedule (see [`SAMPLE_BASE`]).
    inserts_since_sample: u64,
    next_sample: u64,
    disk: Arc<dyn BlockStore>,
    counters: Arc<StorageCounters>,
    namespace: u64,
    runs: u64,
    /// Dictionary-encode string keys in spilled runs (`--dict-keys`;
    /// off = ablation, every occurrence written inline).
    dict_keys: bool,
}

impl<K, V> ExternalMerger<K, V>
where
    K: Ord + Hash + Eq + DataKey + HeapSize,
    V: Encode + Decode + HeapSize,
{
    /// A merger spilling runs beyond `threshold` estimated in-flight
    /// bytes. `namespace` must be unique per merger
    /// ([`super::fresh_spill_namespace`]); `counters` is the storage
    /// domain the spill volume is charged to.
    pub fn new(
        threshold: u64,
        disk: Arc<dyn BlockStore>,
        counters: Arc<StorageCounters>,
        namespace: u64,
    ) -> Self {
        Self {
            mem: HashMap::new(),
            mem_bytes: 0,
            threshold,
            limit: threshold,
            inserts_since_sample: 0,
            next_sample: SAMPLE_BASE,
            disk,
            counters,
            namespace,
            runs: 0,
            dict_keys: true,
        }
    }

    /// Toggle per-run key dictionaries (default on). The run format is
    /// self-describing, so readers need no matching knob.
    pub fn with_dict_keys(mut self, dict_keys: bool) -> Self {
        self.dict_keys = dict_keys;
        self
    }

    /// Estimated bytes currently held in memory.
    pub fn mem_bytes(&self) -> u64 {
        self.mem_bytes
    }

    /// Sorted runs spilled so far.
    pub fn runs(&self) -> u64 {
        self.runs
    }

    fn run_key(&self, run: u64) -> CacheKey {
        CacheKey { namespace: self.namespace, generation: 0, partition: run, splits: 0 }
    }

    /// Fold one emission into the accumulator, spilling a sorted run if
    /// the in-flight estimate crosses the budget.
    ///
    /// Size accounting is an upper bound corrected by periodic exact
    /// samples: a combining insert charges the incoming value's own
    /// estimate (near-exact for growing accumulators like postings
    /// vectors; an over-count for fixed-size ones, pulled back down at
    /// the next sample) — never an `O(|accumulated value|)` walk per
    /// insert.
    pub fn insert(&mut self, key: K, value: V, combine: impl Fn(&mut V, V)) {
        match self.mem.entry(key) {
            std::collections::hash_map::Entry::Occupied(mut e) => {
                self.mem_bytes += value.heap_bytes() as u64;
                combine(e.get_mut(), value);
            }
            std::collections::hash_map::Entry::Vacant(e) => {
                self.mem_bytes +=
                    e.key().heap_bytes() as u64 + value.heap_bytes() as u64 + PAIR_OVERHEAD;
                e.insert(value);
            }
        }
        self.after_insert();
    }

    /// Fold one *decoded* emission without materializing its key unless
    /// it is new: a borrowed-key probe ([`DataKey::map_get_mut`]) hits
    /// the accumulator directly and only a first-seen key is cloned out
    /// of `dict`'s arena — the zero-copy half of the shuffle read path.
    pub fn insert_ref(
        &mut self,
        kref: K::Ref,
        dict: &DictReader,
        value: V,
        combine: impl Fn(&mut V, V),
    ) {
        match K::map_get_mut(&mut self.mem, &kref, dict) {
            Some(slot) => {
                self.mem_bytes += value.heap_bytes() as u64;
                combine(slot, value);
            }
            None => {
                let key = K::ref_materialize(&kref, dict);
                self.mem_bytes +=
                    key.heap_bytes() as u64 + value.heap_bytes() as u64 + PAIR_OVERHEAD;
                self.mem.insert(key, value);
            }
        }
        self.after_insert();
    }

    fn after_insert(&mut self) {
        self.inserts_since_sample += 1;
        if self.inserts_since_sample >= self.next_sample {
            self.resample();
        }
        if self.mem_bytes > self.limit {
            self.spill();
        }
    }

    /// Recompute the exact resident estimate and double the sampling
    /// interval (reset to [`SAMPLE_BASE`] by the next spill).
    fn resample(&mut self) {
        self.inserts_since_sample = 0;
        self.next_sample = self.next_sample.saturating_mul(2);
        self.mem_bytes = self
            .mem
            .iter()
            .map(|(k, v)| k.heap_bytes() as u64 + v.heap_bytes() as u64 + PAIR_OVERHEAD)
            .sum();
    }

    /// Sort the resident entries and write them as one run (keys through
    /// a fresh per-run dictionary, savings charged to the counters). On
    /// a write failure the entries stay resident (no data loss) and the
    /// enforced limit doubles until the next successful spill.
    fn spill(&mut self) {
        if self.mem.is_empty() {
            return;
        }
        let mut span = crate::trace::span(crate::trace::SpanCat::SpillRun, "spill-run");
        let mut batch: Vec<(K, V)> = self.mem.drain().collect();
        batch.sort_unstable_by(|a, b| a.0.cmp(&b.0));
        // Concatenated `key (dict) · value (plain)` encodings — no count
        // prefix, so cursors can stream-decode until the payload is
        // exhausted. Each run is its own dictionary scope.
        let mut dict = DictWriter::new(self.dict_keys);
        let mut payload = Vec::new();
        for (k, v) in &batch {
            k.dict_encode(&mut dict, &mut payload);
            v.encode(&mut payload);
        }
        span.set_arg(payload.len() as u64);
        match self.disk.write(self.run_key(self.runs), &payload) {
            Ok(written) => {
                self.counters.record_spill(written);
                self.counters.record_dict(&dict.stats());
                self.runs += 1;
                self.mem_bytes = 0;
                self.limit = self.threshold;
                self.inserts_since_sample = 0;
                self.next_sample = SAMPLE_BASE;
            }
            Err(_) => {
                self.counters.record_spill_failure();
                // Put the batch back; nothing was lost.
                for (k, v) in batch {
                    self.mem.insert(k, v);
                }
                self.limit = self.mem_bytes.max(1).saturating_mul(2);
            }
        }
    }

    /// Merge every spilled run plus the in-memory remainder into the
    /// final combined entries (loser-tree k-way merge; equal keys folded
    /// with `combine` in run order). Heads are compared as borrowed
    /// [`DataKey::Ref`] handles against each run's own [`DictReader`];
    /// a key is materialized exactly once, when it first wins. Consumed
    /// runs are deleted from the block store.
    pub fn finish(mut self, combine: impl Fn(&mut V, V)) -> Vec<(K, V)> {
        if self.runs == 0 {
            return self.mem.drain().collect();
        }
        let _span =
            crate::trace::span_arg(crate::trace::SpanCat::SpillMerge, "spill-merge", self.runs);
        let mut last: Vec<(K, V)> = self.mem.drain().collect();
        last.sort_unstable_by(|a, b| a.0.cmp(&b.0));

        let mut sources: Vec<Run<K, V>> = (0..self.runs)
            .map(|r| Run::from_disk(Arc::clone(&self.disk), self.run_key(r)))
            .collect();
        sources.push(Run::from_mem(last));

        let mut out: Vec<(K, V)> = Vec::new();
        let mut current: Option<(K, V)> = None;
        let mut tree = LoserTree::build(sources.len(), |a, b| better(&sources, a, b));
        loop {
            let winner = tree.winner();
            let Some((kref, v)) = sources[winner].next() else {
                break; // the best source is exhausted => all are
            };
            tree.replay(winner, |a, b| better(&sources, a, b));
            let ctx = &sources[winner].ctx;
            match &mut current {
                Some((ck, cv)) if K::ref_eq_owned(&kref, ctx, ck) => combine(cv, v),
                _ => {
                    if let Some(done) = current.take() {
                        out.push(done);
                    }
                    current = Some((K::ref_materialize(&kref, ctx), v));
                }
            }
        }
        if let Some(done) = current.take() {
            out.push(done);
        }
        for r in 0..self.runs {
            self.disk.delete(&self.run_key(r));
        }
        out
    }
}

/// `true` when source `a`'s head should be emitted before source `b`'s:
/// smaller key first, exhausted sources last, ties by source index (so
/// the merge — and therefore the combine order — is deterministic).
/// Heads are compared as refs against their own run's dictionary
/// ([`DataKey::ref_cmp`] must order exactly like `Ord` on owned keys).
fn better<K: DataKey, V>(sources: &[Run<K, V>], a: usize, b: usize) -> bool {
    match (&sources[a].head, &sources[b].head) {
        (Some((ka, _)), Some((kb, _))) => {
            match K::ref_cmp(ka, &sources[a].ctx, kb, &sources[b].ctx) {
                std::cmp::Ordering::Less => true,
                std::cmp::Ordering::Greater => false,
                std::cmp::Ordering::Equal => a < b,
            }
        }
        (Some(_), None) => true,
        (None, Some(_)) => false,
        (None, None) => a < b,
    }
}

/// One sorted run being merged: a buffered head (as a borrowed key
/// handle + owned value) plus its tail (an in-memory batch or a
/// streaming disk cursor) and the run's dictionary context resolving
/// the handles.
struct Run<K: DataKey, V> {
    head: Option<(K::Ref, V)>,
    tail: RunTail<K, V>,
    /// Dictionary + arena every `K::Ref` in this run points into. A
    /// sibling field (not inside the cursor) so `next()` can borrow the
    /// tail and the context disjointly.
    ctx: DictReader,
}

enum RunTail<K, V> {
    Mem(std::vec::IntoIter<(K, V)>),
    Disk(DiskRunCursor<K, V>),
}

impl<K: DataKey, V: Decode> Run<K, V> {
    fn from_mem(batch: Vec<(K, V)>) -> Self {
        let mut ctx = DictReader::new();
        let mut tail = batch.into_iter();
        let head = match tail.next() {
            Some((k, v)) => Some((K::ref_from_owned(k, &mut ctx), v)),
            None => None,
        };
        Run { head, tail: RunTail::Mem(tail), ctx }
    }

    fn from_disk(store: Arc<dyn BlockStore>, key: CacheKey) -> Self {
        let mut cursor = DiskRunCursor::new(store, key);
        let mut ctx = DictReader::new();
        let head = cursor.pull(&mut ctx);
        Run { head, tail: RunTail::Disk(cursor), ctx }
    }

    fn next(&mut self) -> Option<(K::Ref, V)> {
        let out = self.head.take();
        self.head = match &mut self.tail {
            RunTail::Mem(iter) => match iter.next() {
                Some((k, v)) => Some((K::ref_from_owned(k, &mut self.ctx), v)),
                None => None,
            },
            RunTail::Disk(cursor) => cursor.pull(&mut self.ctx),
        };
        out
    }
}

/// Streaming decoder over one spilled run: fetches the payload in
/// [`RUN_READ_CHUNK`]-sized ranges, decodes one `(K, V)` at a time
/// (keys through the run's [`DictReader`], handed out as arena refs),
/// and verifies the run's checksum once the payload is exhausted. Run
/// corruption is unrecoverable (the spilled entries exist nowhere else),
/// so it panics rather than silently dropping records.
struct DiskRunCursor<K, V> {
    store: Arc<dyn BlockStore>,
    key: CacheKey,
    payload_len: u64,
    expect_checksum: u64,
    /// Payload bytes fetched so far.
    fetched: u64,
    /// Running FNV over fetched bytes.
    hash: u64,
    /// Fetched-but-undecoded bytes (`buf[cursor..]` is live).
    buf: Vec<u8>,
    cursor: usize,
    verified: bool,
    _kv: PhantomData<(K, V)>,
}

impl<K: DataKey, V: Decode> DiskRunCursor<K, V> {
    fn new(store: Arc<dyn BlockStore>, key: CacheKey) -> Self {
        let meta = store
            .meta(&key)
            .unwrap_or_else(|| panic!("spill run {key:?} vanished from the block store"));
        Self {
            store,
            key,
            payload_len: meta.payload_len,
            expect_checksum: meta.checksum,
            fetched: 0,
            hash: CHECKSUM_SEED,
            buf: Vec::new(),
            cursor: 0,
            verified: false,
            _kv: PhantomData,
        }
    }

    fn pull(&mut self, dict: &mut DictReader) -> Option<(K::Ref, V)> {
        loop {
            let live = &self.buf[self.cursor..];
            if !live.is_empty() {
                // Checkpoint the dictionary before every attempt: a
                // record straddling the chunk boundary fails with
                // `Truncated` *after* possibly interning a new entry,
                // and the retry must not register it twice.
                let cp = dict.checkpoint();
                let mut reader = Reader::new(live);
                let decoded = K::dict_decode(&mut reader, dict)
                    .and_then(|kr| V::decode(&mut reader).map(|v| (kr, v)));
                match decoded {
                    Ok(kv) => {
                        self.cursor += live.len() - reader.remaining();
                        return Some(kv);
                    }
                    Err(DecodeError::Truncated { .. }) if self.fetched < self.payload_len => {
                        // Fall through and fetch more.
                        dict.rollback(cp);
                    }
                    Err(e) => panic!("spill run {:?} is corrupt: {e}", self.key),
                }
            } else if self.fetched >= self.payload_len {
                if !self.verified {
                    self.verified = true;
                    if self.hash != self.expect_checksum {
                        panic!("spill run {:?} failed checksum verification", self.key);
                    }
                }
                return None;
            }
            // Compact and refill.
            self.buf.drain(..self.cursor);
            self.cursor = 0;
            let chunk = self
                .store
                .read_range(&self.key, self.fetched, RUN_READ_CHUNK)
                .unwrap_or_else(|e| panic!("reading spill run {:?}: {e}", self.key))
                .unwrap_or_else(|| panic!("spill run {:?} vanished mid-merge", self.key));
            assert!(
                !chunk.is_empty(),
                "spill run {:?} shorter than its recorded length",
                self.key
            );
            self.hash = checksum(self.hash, &chunk);
            self.fetched += chunk.len() as u64;
            self.buf.extend_from_slice(&chunk);
        }
    }
}

/// Tournament loser tree over `leaves` competitors: internal nodes hold
/// the loser of their subtree's match, the root slot holds the overall
/// winner. `better(a, b)` says whether competitor `a` beats `b`; after
/// consuming the winner's item, [`replay`](LoserTree::replay) restores
/// the invariant along one leaf-to-root path — `O(log k)` per record,
/// the structure real external sorters use for wide merges.
pub struct LoserTree {
    /// `tree[0]` = current winner; `tree[1..]` = per-node losers.
    tree: Vec<usize>,
    leaves: usize,
}

impl LoserTree {
    /// Seed the bracket: every leaf plays up to the first undecided slot.
    pub fn build(leaves: usize, better: impl Fn(usize, usize) -> bool) -> Self {
        assert!(leaves > 0, "a merge needs at least one source");
        let mut tree = vec![usize::MAX; leaves];
        for leaf in 0..leaves {
            let mut winner = leaf;
            let mut node = (leaves + leaf) / 2;
            while node != 0 && tree[node] != usize::MAX {
                if better(tree[node], winner) {
                    std::mem::swap(&mut tree[node], &mut winner);
                }
                node /= 2;
            }
            tree[node] = winner;
        }
        Self { tree, leaves }
    }

    /// The current overall winner.
    pub fn winner(&self) -> usize {
        self.tree[0]
    }

    /// Re-run the matches on `leaf`'s path to the root (call after the
    /// winner's item was consumed and its source advanced).
    pub fn replay(&mut self, leaf: usize, better: impl Fn(usize, usize) -> bool) {
        debug_assert!(leaf < self.leaves);
        let mut winner = leaf;
        let mut node = (self.leaves + leaf) / 2;
        while node != 0 {
            if better(self.tree[node], winner) {
                std::mem::swap(&mut self.tree[node], &mut winner);
            }
            node /= 2;
        }
        self.tree[0] = winner;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::storage::{fresh_spill_namespace, DiskTier};
    use std::collections::HashMap;

    fn sum(acc: &mut u64, v: u64) {
        *acc += v;
    }

    fn merger(threshold: u64) -> (ExternalMerger<String, u64>, Arc<DiskTier>) {
        let disk = Arc::new(DiskTier::new(None));
        let counters = Arc::clone(disk.counters());
        let m = ExternalMerger::new(
            threshold,
            Arc::clone(&disk) as Arc<dyn BlockStore>,
            counters,
            fresh_spill_namespace(),
        );
        (m, disk)
    }

    fn reference(pairs: &[(String, u64)]) -> HashMap<String, u64> {
        let mut acc = HashMap::new();
        for (k, v) in pairs {
            *acc.entry(k.clone()).or_insert(0) += v;
        }
        acc
    }

    fn pairs(n: usize) -> Vec<(String, u64)> {
        // Repeating keys in a scrambled order.
        (0..n).map(|i| (format!("key{:03}", (i * 7) % 23), (i as u64) + 1)).collect()
    }

    #[test]
    fn no_spill_below_threshold() {
        let (mut m, disk) = merger(u64::MAX);
        let input = pairs(200);
        for (k, v) in input.clone() {
            m.insert(k, v, sum);
        }
        assert_eq!(m.runs(), 0);
        let got: HashMap<String, u64> = m.finish(sum).into_iter().collect();
        assert_eq!(got, reference(&input));
        assert_eq!(disk.counters().snapshot().spilled_bytes, 0);
    }

    #[test]
    fn spilled_merge_matches_in_memory_fold() {
        // 23 distinct keys at ~60 estimated bytes each: every threshold
        // below ~1.4 KB is guaranteed to spill.
        for threshold in [0u64, 1, 64, 512] {
            let (mut m, disk) = merger(threshold);
            let input = pairs(300);
            for (k, v) in input.clone() {
                m.insert(k, v, sum);
            }
            assert!(m.runs() > 0, "threshold {threshold} must spill");
            let got: HashMap<String, u64> = m.finish(sum).into_iter().collect();
            assert_eq!(got, reference(&input), "threshold {threshold}");
            let stats = disk.counters().snapshot();
            assert!(stats.spilled_bytes > 0);
            assert!(stats.spill_runs >= 1);
            assert!(disk.is_empty(), "consumed runs are deleted");
        }
    }

    #[test]
    fn dict_off_merge_is_identical() {
        for dict_keys in [true, false] {
            let (m, disk) = merger(64);
            let mut m = m.with_dict_keys(dict_keys);
            let input = pairs(300);
            for (k, v) in input.clone() {
                m.insert(k, v, sum);
            }
            assert!(m.runs() > 0);
            let got: HashMap<String, u64> = m.finish(sum).into_iter().collect();
            assert_eq!(got, reference(&input), "dict_keys {dict_keys}");
            let stats = disk.counters().snapshot();
            // Runs repeat few distinct keys, so the dictionary must have
            // recorded savings exactly when enabled.
            assert_eq!(stats.dict_refs > 0, dict_keys, "dict_keys {dict_keys}: {stats:?}");
            assert!(stats.dict_key_enc_bytes > 0);
        }
    }

    #[test]
    fn insert_ref_matches_owned_insert() {
        let (mut owned, _d1) = merger(u64::MAX);
        let (mut by_ref, _d2) = merger(u64::MAX);
        let input = pairs(200);
        let mut dict = DictReader::new();
        for (k, v) in input.clone() {
            owned.insert(k, v, sum);
        }
        for (k, v) in input {
            let kref = String::ref_from_owned(k, &mut dict);
            by_ref.insert_ref(kref, &dict, v, sum);
        }
        let a: HashMap<String, u64> = owned.finish(sum).into_iter().collect();
        let b: HashMap<String, u64> = by_ref.finish(sum).into_iter().collect();
        assert_eq!(a, b);
    }

    #[test]
    fn spilled_output_is_key_sorted() {
        let (mut m, _disk) = merger(0);
        for (k, v) in pairs(100) {
            m.insert(k, v, sum);
        }
        let out = m.finish(sum);
        assert!(out.windows(2).all(|w| w[0].0 < w[1].0), "merged output is sorted + deduped");
    }

    #[test]
    fn zero_threshold_spills_every_insert() {
        let (mut m, _disk) = merger(0);
        for (k, v) in pairs(50) {
            m.insert(k, v, sum);
        }
        assert_eq!(m.runs(), 50);
        assert_eq!(m.mem_bytes(), 0);
    }

    #[test]
    fn empty_merger_finishes_empty() {
        let (m, _disk) = merger(0);
        assert!(m.finish(sum).is_empty());
    }

    #[test]
    fn run_cursor_streams_across_chunk_boundaries() {
        // Each record encodes to ~80 KB — larger than the 64 KiB read
        // chunk, so every record straddles a chunk boundary.
        let disk = Arc::new(DiskTier::new(None));
        let mut m: ExternalMerger<String, Vec<u32>> = ExternalMerger::new(
            8 << 10,
            Arc::clone(&disk) as Arc<dyn BlockStore>,
            Arc::clone(disk.counters()),
            fresh_spill_namespace(),
        );
        let mut expect: HashMap<String, Vec<u32>> = HashMap::new();
        for i in 0..12u32 {
            let key = format!("k{}", i % 4);
            let val: Vec<u32> = (0..20_000).map(|j| i * 100_000 + j).collect();
            expect.entry(key.clone()).or_default().extend(&val);
            m.insert(key, val, |acc, mut v| acc.append(&mut v));
        }
        assert!(m.runs() > 1);
        let got: HashMap<String, Vec<u32>> =
            m.finish(|acc, mut v| acc.append(&mut v)).into_iter().collect();
        // Append order differs from insertion order across runs; compare
        // as multisets per key (the workload contract sorts in finalize).
        assert_eq!(got.len(), expect.len());
        for (k, mut v) in got {
            let mut e = expect.remove(&k).expect("key present");
            v.sort_unstable();
            e.sort_unstable();
            assert_eq!(v, e, "key {k}");
        }
    }

    #[test]
    fn loser_tree_merges_sorted_sequences() {
        let runs: Vec<Vec<u32>> = vec![
            vec![1, 4, 7, 10],
            vec![2, 5, 8],
            vec![],
            vec![3, 6, 9, 11, 12],
            vec![1, 1, 2],
        ];
        fn head_better(heads: &[Option<u32>], a: usize, b: usize) -> bool {
            match (heads[a], heads[b]) {
                (Some(x), Some(y)) => x < y || (x == y && a < b),
                (Some(_), None) => true,
                (None, Some(_)) => false,
                (None, None) => a < b,
            }
        }
        let mut iters: Vec<std::vec::IntoIter<u32>> =
            runs.iter().cloned().map(|r| r.into_iter()).collect();
        let mut heads: Vec<Option<u32>> = iters.iter_mut().map(|it| it.next()).collect();
        let mut tree = LoserTree::build(heads.len(), |a, b| head_better(&heads, a, b));
        let mut out = Vec::new();
        loop {
            let w = tree.winner();
            let Some(x) = heads[w] else { break };
            out.push(x);
            heads[w] = iters[w].next();
            tree.replay(w, |a, b| head_better(&heads, a, b));
        }
        let mut expect: Vec<u32> = runs.into_iter().flatten().collect();
        expect.sort_unstable();
        assert_eq!(out, expect);
    }

    // Mid-spill write-failure tolerance (no data loss, budget backoff)
    // is covered by `prop_external_merger_matches_in_memory_fold` in
    // `tests/property_suite.rs`, whose failure-injecting BlockStore
    // double sweeps several failure schedules.
}
