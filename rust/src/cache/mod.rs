//! The partition cache — the memory tier of the storage hierarchy, and
//! the subsystem behind "Spark is an *in-memory* implementation of
//! MapReduce".
//!
//! The paper's comparison runs single-pass jobs, where caching never pays
//! off. Iterative jobs (PageRank, k-means) re-read their input every
//! round, and this module is what turns that re-read into a memory hit:
//! a **memory-budgeted, size-aware partition store** with LRU eviction,
//! per-entry byte accounting, and hit/miss/evict statistics that the job
//! layer surfaces into [`crate::mapreduce::JobReport`].
//!
//! Since the tiered storage subsystem ([`crate::storage`]) landed,
//! [`PartitionCache`] is an alias for [`crate::storage::TieredStore`]:
//! the same store, now optionally backed by a
//! [`DiskTier`](crate::storage::DiskTier). Without one (the default,
//! and everything this module's docs describe) behavior is exactly the
//! PR 3 cache: evicted means gone, and the engines recompute. With one
//! attached ([`TieredStore::with_spill`](crate::storage::TieredStore::with_spill),
//! the `--spill-threshold` path), entries inserted through
//! `put_encoded` **demote to disk under memory pressure and promote back
//! on access** — disk-backed persist instead of lossy evict+recompute.
//!
//! Both engines sit on top of it:
//!
//! * the Spark sim's [`Rdd::persist`](crate::engines::spark::Rdd::persist)
//!   / `cache()` stores materialized partitions here and — when the entry
//!   is not in *any* tier — **recomputes from lineage** (exactly Spark's
//!   `MemoryStore` + `BlockManager` contract);
//! * Blaze caches **parsed input splits** keyed by
//!   `(relation, generation, node)` so later iterations of an iterative
//!   job skip tokenization (see
//!   [`crate::engines::blaze::run_workload_cached`]).
//!
//! # The budget knob ↔ `spark.memory.fraction`
//!
//! [`CacheBudget`] plays the role of Spark's storage memory pool: real
//! Spark sizes it as `spark.memory.fraction × (heap − 300 MiB)` (0.6 by
//! default, shared with execution, `spark.memory.storageFraction`
//! protecting half of it), and evicts cached blocks LRU-first when the
//! pool fills. We model the *consequence* of that machinery, not its
//! negotiation: `CacheBudget::Bytes(n)` is the storage pool size, entries
//! above the whole budget are rejected outright (Spark: "block too large
//! to cache") unless a disk tier is attached, and eviction is
//! least-recently-used by entry. Two settings bracket every experiment:
//!
//! * `CacheBudget::Unbounded` — a heap big enough to hold the working set
//!   (the regime in which Spark's in-memory claim is usually stated);
//! * `CacheBudget::Bytes(0)` — no storage pool at all: every round
//!   recomputes from scratch, the ablation that measures what the cache
//!   buys. Budget 0 disables the disk tier too — "storage off" must
//!   measure recomputation, not a spill-shaped detour.
//!
//! Sizes are *estimates* supplied by the caller (via
//! [`crate::storage::HeapSize`], re-exported here), mirroring Spark's
//! `SizeEstimator`: accounting is approximate by design, the invariant —
//! cached bytes never exceed the budget — is exact with respect to those
//! estimates.

// The store itself lives in the storage subsystem; this module keeps the
// cache-facing names (and the identity types below) stable.
pub use crate::storage::HeapSize;
pub use crate::storage::TieredStore as PartitionCache;
pub use crate::storage::{BasePolicy, PolicySpec};

/// Memory budget of a [`PartitionCache`] — the `spark.memory.fraction`
/// stand-in (see the module docs for the mapping).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CacheBudget {
    /// Cache everything, evict nothing.
    Unbounded,
    /// At most this many (estimated) bytes live in the cache; `Bytes(0)`
    /// disables caching entirely — the recompute-every-round ablation.
    Bytes(u64),
}

impl CacheBudget {
    /// Parse a CLI-ish budget: `unbounded`/`inf`, `none`/`off`, or a size
    /// (`64MB`, `512kb`, `4096`).
    pub fn parse(s: &str) -> Option<CacheBudget> {
        match s.trim().to_ascii_lowercase().as_str() {
            "unbounded" | "inf" | "unlimited" => Some(CacheBudget::Unbounded),
            "none" | "off" => Some(CacheBudget::Bytes(0)),
            other => crate::util::cli::parse_bytes(other).map(CacheBudget::Bytes),
        }
    }
}

impl std::fmt::Display for CacheBudget {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CacheBudget::Unbounded => write!(f, "unbounded"),
            CacheBudget::Bytes(b) => write!(f, "{}", crate::util::stats::fmt_bytes(*b)),
        }
    }
}

/// Identity of one cached partition (and of one stored block — the
/// storage subsystem keys its tiers with this type too; see the
/// namespace map in [`crate::storage`]).
///
/// * `namespace` — which dataset: an input relation index for the
///   iterative runners, or a fresh RDD persist id on the Spark sim.
/// * `generation` — version of that dataset's *contents*; bumping it
///   invalidates (by never matching) every entry of older generations,
///   which the writer then drops via
///   [`PartitionCache::invalidate_generations_below`] (bounded budgets
///   would also age them out through LRU).
/// * `partition` — the split: a node rank on Blaze, a partition index on
///   the Spark sim.
/// * `splits` — how many splits the dataset was cut into when this entry
///   was produced (node count on Blaze, RDD partition count on the Spark
///   sim). Keying on the shape means a cache shared across jobs with
///   different cluster shapes can never serve a split cut for a
///   different decomposition.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct CacheKey {
    pub namespace: u64,
    pub generation: u64,
    pub partition: u64,
    pub splits: u64,
}

/// Counter snapshot of one cache (counters are cumulative since creation;
/// `bytes_cached`/`entries` are point-in-time gauges). A hit served from
/// the disk tier counts as a hit — the caller did not recompute.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
    pub insertions: u64,
    pub evictions: u64,
    /// Entries refused memory admission: they alone exceed the whole
    /// budget (all entries, when the budget is 0), or a TinyLFU-style
    /// admission filter turned a cold newcomer away. With a disk tier
    /// attached, size- and filter-rejected `put_encoded` entries still
    /// land on disk (only budget 0 loses them).
    pub rejected: u64,
    pub bytes_cached: u64,
    pub entries: u64,
}

impl CacheStats {
    /// Fraction of lookups served from memory.
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }

    /// Counters accumulated since `earlier` (gauges keep `self`'s value) —
    /// what one job or one iteration did to a shared cache.
    pub fn delta_since(&self, earlier: &CacheStats) -> CacheStats {
        CacheStats {
            hits: self.hits - earlier.hits,
            misses: self.misses - earlier.misses,
            insertions: self.insertions - earlier.insertions,
            evictions: self.evictions - earlier.evictions,
            rejected: self.rejected - earlier.rejected,
            bytes_cached: self.bytes_cached,
            entries: self.entries,
        }
    }
}

impl std::fmt::Display for CacheStats {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "hits={} misses={} ({:.0}% hit) evict={} reject={} cached={}",
            self.hits,
            self.misses,
            self.hit_rate() * 100.0,
            self.evictions,
            self.rejected,
            crate::util::stats::fmt_bytes(self.bytes_cached),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::any::Any;
    use std::sync::Arc;

    fn key(p: u64) -> CacheKey {
        CacheKey { namespace: 0, generation: 0, partition: p, splits: 1 }
    }

    fn val(x: u64) -> Arc<dyn Any + Send + Sync> {
        Arc::new(vec![x, x + 1])
    }

    #[test]
    fn hit_returns_stored_value() {
        let c = PartitionCache::new(CacheBudget::Unbounded);
        assert!(c.put(key(1), val(7), 100));
        let got = c.get_typed::<Vec<u64>>(&key(1)).expect("hit");
        assert_eq!(*got, vec![7, 8]);
        let s = c.stats();
        assert_eq!((s.hits, s.misses, s.insertions), (1, 0, 1));
        assert_eq!(s.bytes_cached, 100);
    }

    fn nkey(namespace: u64, generation: u64, partition: u64) -> CacheKey {
        CacheKey { namespace, generation, partition, splits: 1 }
    }

    #[test]
    fn miss_on_generation_or_shape_mismatch() {
        let c = PartitionCache::new(CacheBudget::Unbounded);
        c.put(nkey(3, 0, 0), val(1), 10);
        assert!(c.get(&nkey(3, 1, 0)).is_none(), "newer generation never matches");
        assert!(
            c.get(&CacheKey { namespace: 3, generation: 0, partition: 0, splits: 2 }).is_none(),
            "a different decomposition never matches"
        );
        assert_eq!(c.stats().misses, 2);
    }

    #[test]
    fn zero_sized_entries_are_rejected_at_zero_budget() {
        let c = PartitionCache::new(CacheBudget::Bytes(0));
        assert!(!c.put(key(1), val(1), 0), "Bytes(0) means caching is off");
        assert!(c.is_empty());
        assert_eq!(c.stats().rejected, 1);
    }

    #[test]
    fn invalidate_generations_below_frees_stale_entries() {
        let c = PartitionCache::new(CacheBudget::Unbounded);
        for generation in 0..3 {
            c.put(nkey(7, generation, 0), val(generation), 10);
            c.put(nkey(7, generation, 1), val(generation), 10);
        }
        c.put(nkey(8, 0, 0), val(9), 10); // other namespace: untouched
        assert_eq!(c.invalidate_generations_below(7, 2), 4);
        assert_eq!(c.len(), 3);
        assert_eq!(c.bytes_cached(), 30);
        assert!(c.contains(&nkey(7, 2, 0)) && c.contains(&nkey(7, 2, 1)));
        assert!(c.contains(&nkey(8, 0, 0)));
        assert_eq!(c.stats().evictions, 0, "invalidation is not eviction");
    }

    #[test]
    fn lru_eviction_under_budget() {
        let c = PartitionCache::new(CacheBudget::Bytes(250));
        c.put(key(1), val(1), 100);
        c.put(key(2), val(2), 100);
        // Touch 1 so 2 becomes LRU.
        assert!(c.get(&key(1)).is_some());
        c.put(key(3), val(3), 100); // must evict 2
        assert!(c.contains(&key(1)));
        assert!(!c.contains(&key(2)));
        assert!(c.contains(&key(3)));
        assert_eq!(c.stats().evictions, 1);
        assert!(c.bytes_cached() <= 250);
    }

    #[test]
    fn oversized_entry_is_rejected() {
        let c = PartitionCache::new(CacheBudget::Bytes(64));
        assert!(!c.put(key(1), val(1), 65));
        assert!(c.is_empty());
        assert_eq!(c.stats().rejected, 1);
    }

    #[test]
    fn zero_budget_disables_caching() {
        let c = PartitionCache::new(CacheBudget::Bytes(0));
        assert!(!c.put(key(1), val(1), 1));
        assert!(c.get(&key(1)).is_none());
        let s = c.stats();
        assert_eq!(s.rejected, 1);
        assert_eq!(s.misses, 1);
        assert_eq!(s.hits, 0);
    }

    #[test]
    fn replacing_a_key_adjusts_bytes() {
        let c = PartitionCache::new(CacheBudget::Bytes(300));
        c.put(key(1), val(1), 200);
        c.put(key(1), val(2), 50);
        assert_eq!(c.bytes_cached(), 50);
        assert_eq!(c.len(), 1);
        assert_eq!(*c.get_typed::<Vec<u64>>(&key(1)).unwrap(), vec![2, 3]);
    }

    #[test]
    fn delta_since_subtracts_counters() {
        let c = PartitionCache::new(CacheBudget::Unbounded);
        c.put(key(1), val(1), 10);
        let before = c.stats();
        c.get(&key(1));
        c.get(&key(9));
        let d = c.stats().delta_since(&before);
        assert_eq!((d.hits, d.misses, d.insertions), (1, 1, 0));
    }

    #[test]
    fn budget_parses() {
        assert_eq!(CacheBudget::parse("unbounded"), Some(CacheBudget::Unbounded));
        assert_eq!(CacheBudget::parse("none"), Some(CacheBudget::Bytes(0)));
        assert_eq!(CacheBudget::parse("0"), Some(CacheBudget::Bytes(0)));
        assert_eq!(CacheBudget::parse("64kb"), Some(CacheBudget::Bytes(64 << 10)));
        assert_eq!(CacheBudget::parse("what"), None);
    }

    #[test]
    fn type_mismatch_counts_as_miss() {
        let c = PartitionCache::new(CacheBudget::Unbounded);
        c.put(key(1), val(1), 10);
        assert!(c.get_typed::<Vec<i64>>(&key(1)).is_none(), "stored type is Vec<u64>");
        let s = c.stats();
        assert_eq!(s.hits, 0, "the caller recomputes, so this was no hit: {s:?}");
        assert_eq!(s.misses, 1, "{s:?}");
        // The correctly typed lookup still hits.
        assert!(c.get_typed::<Vec<u64>>(&key(1)).is_some());
        assert_eq!(c.stats().hits, 1);
    }

    #[test]
    fn fits_respects_budget() {
        assert!(PartitionCache::new(CacheBudget::Unbounded).fits(u64::MAX));
        let c = PartitionCache::new(CacheBudget::Bytes(100));
        assert!(c.fits(100));
        assert!(!c.fits(101));
        assert!(!PartitionCache::new(CacheBudget::Bytes(0)).fits(0), "Bytes(0) admits nothing");
    }

    #[test]
    fn clear_keeps_counters() {
        let c = PartitionCache::new(CacheBudget::Unbounded);
        c.put(key(1), val(1), 10);
        c.clear();
        assert!(c.is_empty());
        assert_eq!(c.bytes_cached(), 0);
        assert_eq!(c.stats().insertions, 1);
    }
}
