//! In-memory partition cache — the subsystem behind "Spark is an
//! *in-memory* implementation of MapReduce".
//!
//! The paper's comparison runs single-pass jobs, where caching never pays
//! off. Iterative jobs (PageRank, k-means) re-read their input every
//! round, and this module is what turns that re-read into a memory hit:
//! a **memory-budgeted, size-aware partition store** with LRU eviction,
//! per-entry byte accounting, and hit/miss/evict statistics that the job
//! layer surfaces into [`crate::mapreduce::JobReport`].
//!
//! Both engines sit on top of it:
//!
//! * the Spark sim's [`Rdd::persist`](crate::engines::spark::Rdd::persist)
//!   / `cache()` stores materialized partitions here and **recomputes from
//!   lineage** when an entry was evicted (exactly Spark's
//!   `MemoryStore` + `BlockManager` contract);
//! * Blaze caches **parsed input splits** keyed by
//!   `(relation, generation, node)` so later iterations of an iterative
//!   job skip tokenization (see
//!   [`crate::engines::blaze::run_workload_cached`]).
//!
//! # The budget knob ↔ `spark.memory.fraction`
//!
//! [`CacheBudget`] plays the role of Spark's storage memory pool: real
//! Spark sizes it as `spark.memory.fraction × (heap − 300 MiB)` (0.6 by
//! default, shared with execution, `spark.memory.storageFraction`
//! protecting half of it), and evicts cached blocks LRU-first when the
//! pool fills. We model the *consequence* of that machinery, not its
//! negotiation: `CacheBudget::Bytes(n)` is the storage pool size, entries
//! above the whole budget are rejected outright (Spark: "block too large
//! to cache"), and eviction is least-recently-used by entry. Two settings
//! bracket every experiment:
//!
//! * `CacheBudget::Unbounded` — a heap big enough to hold the working set
//!   (the regime in which Spark's in-memory claim is usually stated);
//! * `CacheBudget::Bytes(0)` — no storage pool at all: every round
//!   recomputes from scratch, the ablation that measures what the cache
//!   buys.
//!
//! Sizes are *estimates* supplied by the caller (via
//! [`crate::engines::spark::HeapSize`]), mirroring Spark's
//! `SizeEstimator`: accounting is approximate by design, the invariant —
//! cached bytes never exceed the budget — is exact with respect to those
//! estimates.

use std::any::Any;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering::Relaxed};
use std::sync::{Arc, Mutex};

/// Memory budget of a [`PartitionCache`] — the `spark.memory.fraction`
/// stand-in (see the module docs for the mapping).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CacheBudget {
    /// Cache everything, evict nothing.
    Unbounded,
    /// At most this many (estimated) bytes live in the cache; `Bytes(0)`
    /// disables caching entirely — the recompute-every-round ablation.
    Bytes(u64),
}

impl CacheBudget {
    /// Parse a CLI-ish budget: `unbounded`/`inf`, `none`/`off`, or a size
    /// (`64MB`, `512kb`, `4096`).
    pub fn parse(s: &str) -> Option<CacheBudget> {
        match s.trim().to_ascii_lowercase().as_str() {
            "unbounded" | "inf" | "unlimited" => Some(CacheBudget::Unbounded),
            "none" | "off" => Some(CacheBudget::Bytes(0)),
            other => crate::util::cli::parse_bytes(other).map(CacheBudget::Bytes),
        }
    }
}

impl std::fmt::Display for CacheBudget {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CacheBudget::Unbounded => write!(f, "unbounded"),
            CacheBudget::Bytes(b) => write!(f, "{}", crate::util::stats::fmt_bytes(*b)),
        }
    }
}

/// Identity of one cached partition.
///
/// * `namespace` — which dataset: an input relation index for the
///   iterative runners, or a fresh RDD persist id on the Spark sim.
/// * `generation` — version of that dataset's *contents*; bumping it
///   invalidates (by never matching) every entry of older generations,
///   which the writer then drops via
///   [`PartitionCache::invalidate_generations_below`] (bounded budgets
///   would also age them out through LRU).
/// * `partition` — the split: a node rank on Blaze, a partition index on
///   the Spark sim.
/// * `splits` — how many splits the dataset was cut into when this entry
///   was produced (node count on Blaze, RDD partition count on the Spark
///   sim). Keying on the shape means a cache shared across jobs with
///   different cluster shapes can never serve a split cut for a
///   different decomposition.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct CacheKey {
    pub namespace: u64,
    pub generation: u64,
    pub partition: u64,
    pub splits: u64,
}

/// Counter snapshot of one cache (counters are cumulative since creation;
/// `bytes_cached`/`entries` are point-in-time gauges).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
    pub insertions: u64,
    pub evictions: u64,
    /// Entries refused because they alone exceed the whole budget (all
    /// entries, when the budget is 0).
    pub rejected: u64,
    pub bytes_cached: u64,
    pub entries: u64,
}

impl CacheStats {
    /// Fraction of lookups served from memory.
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }

    /// Counters accumulated since `earlier` (gauges keep `self`'s value) —
    /// what one job or one iteration did to a shared cache.
    pub fn delta_since(&self, earlier: &CacheStats) -> CacheStats {
        CacheStats {
            hits: self.hits - earlier.hits,
            misses: self.misses - earlier.misses,
            insertions: self.insertions - earlier.insertions,
            evictions: self.evictions - earlier.evictions,
            rejected: self.rejected - earlier.rejected,
            bytes_cached: self.bytes_cached,
            entries: self.entries,
        }
    }
}

impl std::fmt::Display for CacheStats {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "hits={} misses={} ({:.0}% hit) evict={} reject={} cached={}",
            self.hits,
            self.misses,
            self.hit_rate() * 100.0,
            self.evictions,
            self.rejected,
            crate::util::stats::fmt_bytes(self.bytes_cached),
        )
    }
}

/// One cached value: type-erased payload + its estimated size + recency.
struct Slot {
    value: Arc<dyn Any + Send + Sync>,
    bytes: u64,
    last_used: u64,
}

#[derive(Default)]
struct Inner {
    slots: HashMap<CacheKey, Slot>,
    bytes: u64,
    /// Monotonic recency clock; bumped on every touch.
    tick: u64,
}

/// The memory-budgeted, size-aware partition store (see module docs).
///
/// Thread-safe and cheap to share (`Arc<PartitionCache>`); both engines
/// and the iterative driver hold the same instance so cached partitions
/// survive across job rounds.
pub struct PartitionCache {
    budget: CacheBudget,
    inner: Mutex<Inner>,
    hits: AtomicU64,
    misses: AtomicU64,
    insertions: AtomicU64,
    evictions: AtomicU64,
    rejected: AtomicU64,
}

impl std::fmt::Debug for PartitionCache {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("PartitionCache")
            .field("budget", &self.budget)
            .field("stats", &self.stats())
            .finish()
    }
}

impl PartitionCache {
    pub fn new(budget: CacheBudget) -> Self {
        Self {
            budget,
            inner: Mutex::new(Inner::default()),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            insertions: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
            rejected: AtomicU64::new(0),
        }
    }

    pub fn budget(&self) -> CacheBudget {
        self.budget
    }

    /// `true` when the budget is `Bytes(0)`: nothing can ever be admitted.
    /// Engines check this up front so the recompute ablation doesn't pay
    /// for cloning and size-estimating partitions that are certain to be
    /// rejected — the ablation must measure recomputation, not a
    /// caching-shaped detour.
    pub fn is_disabled(&self) -> bool {
        self.budget == CacheBudget::Bytes(0)
    }

    /// Could an entry of `bytes` estimated size ever be admitted? `false`
    /// means [`put`](Self::put) is guaranteed to reject it — callers use
    /// this to skip the deep clone a doomed insert would need. Does not
    /// touch the stats (only an actual `put` counts as a rejection).
    pub fn fits(&self, bytes: u64) -> bool {
        match self.budget {
            CacheBudget::Unbounded => true,
            CacheBudget::Bytes(limit) => limit > 0 && bytes <= limit,
        }
    }

    /// Look up a partition. A hit bumps the entry's recency (it becomes
    /// the most recently used) and is counted in the stats.
    pub fn get(&self, key: &CacheKey) -> Option<Arc<dyn Any + Send + Sync>> {
        let mut inner = self.inner.lock().unwrap();
        inner.tick += 1;
        let tick = inner.tick;
        match inner.slots.get_mut(key) {
            Some(slot) => {
                slot.last_used = tick;
                self.hits.fetch_add(1, Relaxed);
                Some(Arc::clone(&slot.value))
            }
            None => {
                self.misses.fetch_add(1, Relaxed);
                None
            }
        }
    }

    /// [`get`](Self::get) plus a downcast to the stored type. A type
    /// mismatch behaves — and is counted — as a **miss**: the caller will
    /// recompute, so the hit the raw lookup recorded is reclassified.
    /// (Mismatches cannot happen when every writer of a namespace stores
    /// one type, which is what the engines do.)
    pub fn get_typed<T: Any + Send + Sync>(&self, key: &CacheKey) -> Option<Arc<T>> {
        match self.get(key)?.downcast::<T>() {
            Ok(v) => Some(v),
            Err(_) => {
                self.hits.fetch_sub(1, Relaxed);
                self.misses.fetch_add(1, Relaxed);
                None
            }
        }
    }

    /// Insert a partition of `bytes` estimated size, evicting
    /// least-recently-used entries until it fits. Returns `false` (and
    /// counts a rejection) when the entry alone exceeds the whole budget;
    /// a budget of 0 rejects **everything**, even zero-byte entries —
    /// `Bytes(0)` means caching is off.
    pub fn put(&self, key: CacheKey, value: Arc<dyn Any + Send + Sync>, bytes: u64) -> bool {
        if let CacheBudget::Bytes(limit) = self.budget {
            if limit == 0 || bytes > limit {
                self.rejected.fetch_add(1, Relaxed);
                return false;
            }
        }
        let mut inner = self.inner.lock().unwrap();
        if let Some(old) = inner.slots.remove(&key) {
            inner.bytes -= old.bytes;
        }
        if let CacheBudget::Bytes(limit) = self.budget {
            while inner.bytes + bytes > limit {
                let lru = inner
                    .slots
                    .iter()
                    .min_by_key(|(_, s)| s.last_used)
                    .map(|(k, _)| *k)
                    .expect("over budget with no entries");
                let victim = inner.slots.remove(&lru).unwrap();
                inner.bytes -= victim.bytes;
                self.evictions.fetch_add(1, Relaxed);
            }
        }
        inner.tick += 1;
        let tick = inner.tick;
        inner.bytes += bytes;
        inner.slots.insert(key, Slot { value, bytes, last_used: tick });
        self.insertions.fetch_add(1, Relaxed);
        true
    }

    /// Is `key` currently resident? Does not touch recency or stats
    /// (observation hook for tests and diagnostics).
    pub fn contains(&self, key: &CacheKey) -> bool {
        self.inner.lock().unwrap().slots.contains_key(key)
    }

    /// Drop every resident entry of `namespace` with a generation older
    /// than `keep_generation` — the writer's hook for freeing splits that
    /// can never be read again (the iterative driver calls this as it
    /// bumps the fed-back state relation's generation, so an unbounded
    /// cache does not accumulate one dead parsed state per round).
    /// Returns how many entries were dropped. Not counted as evictions:
    /// these are deliberate removals, not budget pressure.
    pub fn invalidate_generations_below(&self, namespace: u64, keep_generation: u64) -> usize {
        let mut inner = self.inner.lock().unwrap();
        let victims: Vec<CacheKey> = inner
            .slots
            .keys()
            .filter(|k| k.namespace == namespace && k.generation < keep_generation)
            .copied()
            .collect();
        for k in &victims {
            let slot = inner.slots.remove(k).unwrap();
            inner.bytes -= slot.bytes;
        }
        victims.len()
    }

    /// Estimated bytes currently resident.
    pub fn bytes_cached(&self) -> u64 {
        self.inner.lock().unwrap().bytes
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().slots.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Drop every entry (counters are kept — they are cumulative).
    pub fn clear(&self) {
        let mut inner = self.inner.lock().unwrap();
        inner.slots.clear();
        inner.bytes = 0;
    }

    pub fn stats(&self) -> CacheStats {
        let (bytes_cached, entries) = {
            let inner = self.inner.lock().unwrap();
            (inner.bytes, inner.slots.len() as u64)
        };
        CacheStats {
            hits: self.hits.load(Relaxed),
            misses: self.misses.load(Relaxed),
            insertions: self.insertions.load(Relaxed),
            evictions: self.evictions.load(Relaxed),
            rejected: self.rejected.load(Relaxed),
            bytes_cached,
            entries,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn key(p: u64) -> CacheKey {
        CacheKey { namespace: 0, generation: 0, partition: p, splits: 1 }
    }

    fn val(x: u64) -> Arc<dyn Any + Send + Sync> {
        Arc::new(vec![x, x + 1])
    }

    #[test]
    fn hit_returns_stored_value() {
        let c = PartitionCache::new(CacheBudget::Unbounded);
        assert!(c.put(key(1), val(7), 100));
        let got = c.get_typed::<Vec<u64>>(&key(1)).expect("hit");
        assert_eq!(*got, vec![7, 8]);
        let s = c.stats();
        assert_eq!((s.hits, s.misses, s.insertions), (1, 0, 1));
        assert_eq!(s.bytes_cached, 100);
    }

    fn nkey(namespace: u64, generation: u64, partition: u64) -> CacheKey {
        CacheKey { namespace, generation, partition, splits: 1 }
    }

    #[test]
    fn miss_on_generation_or_shape_mismatch() {
        let c = PartitionCache::new(CacheBudget::Unbounded);
        c.put(nkey(3, 0, 0), val(1), 10);
        assert!(c.get(&nkey(3, 1, 0)).is_none(), "newer generation never matches");
        assert!(
            c.get(&CacheKey { namespace: 3, generation: 0, partition: 0, splits: 2 }).is_none(),
            "a different decomposition never matches"
        );
        assert_eq!(c.stats().misses, 2);
    }

    #[test]
    fn zero_sized_entries_are_rejected_at_zero_budget() {
        let c = PartitionCache::new(CacheBudget::Bytes(0));
        assert!(!c.put(key(1), val(1), 0), "Bytes(0) means caching is off");
        assert!(c.is_empty());
        assert_eq!(c.stats().rejected, 1);
    }

    #[test]
    fn invalidate_generations_below_frees_stale_entries() {
        let c = PartitionCache::new(CacheBudget::Unbounded);
        for generation in 0..3 {
            c.put(nkey(7, generation, 0), val(generation), 10);
            c.put(nkey(7, generation, 1), val(generation), 10);
        }
        c.put(nkey(8, 0, 0), val(9), 10); // other namespace: untouched
        assert_eq!(c.invalidate_generations_below(7, 2), 4);
        assert_eq!(c.len(), 3);
        assert_eq!(c.bytes_cached(), 30);
        assert!(c.contains(&nkey(7, 2, 0)) && c.contains(&nkey(7, 2, 1)));
        assert!(c.contains(&nkey(8, 0, 0)));
        assert_eq!(c.stats().evictions, 0, "invalidation is not eviction");
    }

    #[test]
    fn lru_eviction_under_budget() {
        let c = PartitionCache::new(CacheBudget::Bytes(250));
        c.put(key(1), val(1), 100);
        c.put(key(2), val(2), 100);
        // Touch 1 so 2 becomes LRU.
        assert!(c.get(&key(1)).is_some());
        c.put(key(3), val(3), 100); // must evict 2
        assert!(c.contains(&key(1)));
        assert!(!c.contains(&key(2)));
        assert!(c.contains(&key(3)));
        assert_eq!(c.stats().evictions, 1);
        assert!(c.bytes_cached() <= 250);
    }

    #[test]
    fn oversized_entry_is_rejected() {
        let c = PartitionCache::new(CacheBudget::Bytes(64));
        assert!(!c.put(key(1), val(1), 65));
        assert!(c.is_empty());
        assert_eq!(c.stats().rejected, 1);
    }

    #[test]
    fn zero_budget_disables_caching() {
        let c = PartitionCache::new(CacheBudget::Bytes(0));
        assert!(!c.put(key(1), val(1), 1));
        assert!(c.get(&key(1)).is_none());
        let s = c.stats();
        assert_eq!(s.rejected, 1);
        assert_eq!(s.misses, 1);
        assert_eq!(s.hits, 0);
    }

    #[test]
    fn replacing_a_key_adjusts_bytes() {
        let c = PartitionCache::new(CacheBudget::Bytes(300));
        c.put(key(1), val(1), 200);
        c.put(key(1), val(2), 50);
        assert_eq!(c.bytes_cached(), 50);
        assert_eq!(c.len(), 1);
        assert_eq!(*c.get_typed::<Vec<u64>>(&key(1)).unwrap(), vec![2, 3]);
    }

    #[test]
    fn delta_since_subtracts_counters() {
        let c = PartitionCache::new(CacheBudget::Unbounded);
        c.put(key(1), val(1), 10);
        let before = c.stats();
        c.get(&key(1));
        c.get(&key(9));
        let d = c.stats().delta_since(&before);
        assert_eq!((d.hits, d.misses, d.insertions), (1, 1, 0));
    }

    #[test]
    fn budget_parses() {
        assert_eq!(CacheBudget::parse("unbounded"), Some(CacheBudget::Unbounded));
        assert_eq!(CacheBudget::parse("none"), Some(CacheBudget::Bytes(0)));
        assert_eq!(CacheBudget::parse("0"), Some(CacheBudget::Bytes(0)));
        assert_eq!(CacheBudget::parse("64kb"), Some(CacheBudget::Bytes(64 << 10)));
        assert_eq!(CacheBudget::parse("what"), None);
    }

    #[test]
    fn type_mismatch_counts_as_miss() {
        let c = PartitionCache::new(CacheBudget::Unbounded);
        c.put(key(1), val(1), 10);
        assert!(c.get_typed::<Vec<i64>>(&key(1)).is_none(), "stored type is Vec<u64>");
        let s = c.stats();
        assert_eq!(s.hits, 0, "the caller recomputes, so this was no hit: {s:?}");
        assert_eq!(s.misses, 1, "{s:?}");
        // The correctly typed lookup still hits.
        assert!(c.get_typed::<Vec<u64>>(&key(1)).is_some());
        assert_eq!(c.stats().hits, 1);
    }

    #[test]
    fn fits_respects_budget() {
        assert!(PartitionCache::new(CacheBudget::Unbounded).fits(u64::MAX));
        let c = PartitionCache::new(CacheBudget::Bytes(100));
        assert!(c.fits(100));
        assert!(!c.fits(101));
        assert!(!PartitionCache::new(CacheBudget::Bytes(0)).fits(0), "Bytes(0) admits nothing");
    }

    #[test]
    fn clear_keeps_counters() {
        let c = PartitionCache::new(CacheBudget::Unbounded);
        c.put(key(1), val(1), 10);
        c.clear();
        assert!(c.is_empty());
        assert_eq!(c.bytes_cached(), 0);
        assert_eq!(c.stats().insertions, 1);
    }
}
