//! k-means clustering — the second iterative workload: centroid
//! assignment/update rounds until no centroid moves.
//!
//! Input shape: each line of the (static) point relation is one point,
//! `c1 c2 ... cd` — integer coordinates on a fixed-point grid (generate
//! them with [`synthesize_points`], or scale your floats by a constant
//! and round once, up front). The fed-back state relation holds one line
//! per centroid: `cid c1 ... cd`.
//!
//! # Fixed-point arithmetic
//!
//! All round arithmetic is integer: squared L2 distances in `i128`
//! (overflow-safe for any realistic coordinate range), coordinate sums in
//! `i64`, and the centroid update `sum / count` in truncating integer
//! division. Results are therefore independent of combine order and
//! **bit-identical** across the serial oracle and both engines; because
//! the state lives on an integer grid, the iteration reaches an *exact*
//! fixed point (delta 0) rather than dithering in float ulps — which is
//! what makes `run_iterative_serial` a true fixed-point oracle.
//!
//! # Round structure
//!
//! * map over a point: assign it to the nearest broadcast centroid
//!   (ties break toward the smallest centroid id) and emit
//!   `(cid, {count: 1, sum: point})`;
//! * map over a centroid state line: emit `(cid, {count: 0, sum: []})` so
//!   empty clusters survive the round;
//! * combine: element-wise [`ClusterAcc`] merge — order-free;
//! * `KMeans::advance`: new centroid = `sum / count` (or unchanged when
//!   the cluster is empty), delta = max coordinate movement in grid units.
//!
//! Point parsing (the `str → Vec<i64>` decode) is the cacheable half: the
//! point relation never changes, so warm rounds skip tokenization.

use std::collections::HashMap;
use std::sync::Arc;

use crate::mapreduce::{CacheableWorkload, IterativeWorkload, JobInputs, Workload};
use crate::storage::HeapSize;
use crate::util::rng::Xoshiro256;
use crate::util::ser::{Decode, DecodeError, Encode, Reader};

/// Relation index of the static point relation.
pub const KM_POINTS: usize = 0;
/// Relation index of the fed-back centroid state relation.
pub const KM_STATE: usize = 1;

/// Shuffle value: partial sufficient statistics of one cluster.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct ClusterAcc {
    /// Points assigned so far.
    pub count: u64,
    /// Per-dimension coordinate sums (zero-extended on merge, so the
    /// empty-cluster marker `{0, []}` is a true identity element).
    pub sum: Vec<i64>,
}

impl Encode for ClusterAcc {
    fn encode(&self, out: &mut Vec<u8>) {
        self.count.encode(out);
        self.sum.encode(out);
    }
}

impl Decode for ClusterAcc {
    fn decode(r: &mut Reader<'_>) -> Result<Self, DecodeError> {
        Ok(Self { count: u64::decode(r)?, sum: Vec::decode(r)? })
    }
}

impl HeapSize for ClusterAcc {
    fn heap_bytes(&self) -> usize {
        16 + self.sum.heap_bytes()
    }
}

/// Parsed form of one record — what the partition cache stores per split.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum KmParsed {
    /// One point of the point relation.
    Point(Vec<i64>),
    /// One centroid id of the state relation.
    Centroid(u64),
}

impl HeapSize for KmParsed {
    fn heap_bytes(&self) -> usize {
        match self {
            KmParsed::Point(p) => p.heap_bytes() + 16,
            KmParsed::Centroid(_) => 16,
        }
    }
}

// Wire form (tag byte + fields) so cached parse blocks can demote to the
// disk tier under memory pressure.
impl Encode for KmParsed {
    fn encode(&self, out: &mut Vec<u8>) {
        match self {
            KmParsed::Point(p) => {
                out.push(0);
                p.encode(out);
            }
            KmParsed::Centroid(cid) => {
                out.push(1);
                cid.encode(out);
            }
        }
    }
}

impl Decode for KmParsed {
    fn decode(r: &mut Reader<'_>) -> Result<Self, DecodeError> {
        match u8::decode(r)? {
            0 => Ok(KmParsed::Point(Vec::decode(r)?)),
            1 => Ok(KmParsed::Centroid(u64::decode(r)?)),
            t => Err(DecodeError::BadTag(t)),
        }
    }
}

/// `c1 c2 ... cd` → coordinates; `None` for blank or malformed lines.
/// The single definition of the point-line grammar — `parse_rel` (which
/// points join rounds) and `KMeans::init_state` (which points seed
/// centroids) must never disagree on it.
fn parse_point(record: &str) -> Option<Vec<i64>> {
    let coords: Result<Vec<i64>, _> = record.split_whitespace().map(str::parse).collect();
    match coords {
        Ok(c) if !c.is_empty() => Some(c),
        _ => None,
    }
}

/// One round of k-means: assignment against the broadcast centroids
/// (built fresh each round by `KMeans::step`).
pub struct KMeansStep {
    /// (cid, coords), sorted by cid — ties in distance break toward the
    /// first (smallest) id, deterministically.
    centroids: Vec<(u64, Vec<i64>)>,
}

impl KMeansStep {
    /// Index of the nearest centroid (squared L2 in `i128`; first wins
    /// ties). `None` when there are no centroids.
    fn nearest(&self, p: &[i64]) -> Option<u64> {
        let mut best: Option<(u64, i128)> = None;
        for (cid, c) in &self.centroids {
            let dims = p.len().max(c.len());
            let mut d = 0i128;
            for i in 0..dims {
                let diff = p.get(i).copied().unwrap_or(0) as i128
                    - c.get(i).copied().unwrap_or(0) as i128;
                d += diff * diff;
            }
            let better = match best {
                None => true,
                Some((_, bd)) => d < bd,
            };
            if better {
                best = Some((*cid, d));
            }
        }
        best.map(|(cid, _)| cid)
    }
}

impl Workload for KMeansStep {
    type Key = u64;
    type Value = ClusterAcc;
    type Output = HashMap<u64, ClusterAcc>;

    fn name(&self) -> &'static str {
        "kmeans"
    }

    fn num_relations(&self) -> usize {
        2
    }

    /// Multi-input stub: engines and oracles route through `map_rel`.
    fn map(&self, _doc: u64, _record: &str, _emit: &mut dyn FnMut(u64, ClusterAcc)) {
        unreachable!("kmeans is multi-input; run it through the iterative driver");
    }

    fn map_rel(&self, rel: usize, doc: u64, record: &str, emit: &mut dyn FnMut(u64, ClusterAcc)) {
        if let Some(p) = self.parse_rel(rel, doc, record) {
            self.map_parsed(rel, &p, emit);
        }
    }

    fn combine(acc: &mut ClusterAcc, v: ClusterAcc) {
        acc.count += v.count;
        if acc.sum.len() < v.sum.len() {
            acc.sum.resize(v.sum.len(), 0);
        }
        for (a, b) in acc.sum.iter_mut().zip(v.sum.iter()) {
            *a += *b;
        }
    }

    fn finalize(&self, entries: Vec<(u64, ClusterAcc)>) -> HashMap<u64, ClusterAcc> {
        entries.into_iter().collect()
    }
}

impl CacheableWorkload for KMeansStep {
    type Parsed = KmParsed;

    fn parse_rel(&self, rel: usize, _doc: u64, record: &str) -> Option<KmParsed> {
        match rel {
            KM_POINTS => parse_point(record).map(KmParsed::Point),
            KM_STATE => record
                .split_whitespace()
                .next()
                .and_then(|t| t.parse().ok())
                .map(KmParsed::Centroid),
            other => panic!("kmeans got relation index {other}"),
        }
    }

    fn map_parsed(&self, _rel: usize, parsed: &KmParsed, emit: &mut dyn FnMut(u64, ClusterAcc)) {
        match parsed {
            KmParsed::Point(p) => {
                if let Some(cid) = self.nearest(p) {
                    emit(cid, ClusterAcc { count: 1, sum: p.clone() });
                }
            }
            // Keep the cluster present even if no point chose it.
            KmParsed::Centroid(cid) => emit(*cid, ClusterAcc::default()),
        }
    }
}

/// The iterative k-means driver workload. Run it with
/// [`run_iterative`](crate::mapreduce::run_iterative) over a single point
/// relation; initial centroids are `k` evenly spaced points of the input.
#[derive(Clone, Copy, Debug)]
pub struct KMeans {
    /// Number of clusters.
    pub k: usize,
}

impl KMeans {
    pub fn new(k: usize) -> Self {
        assert!(k > 0, "kmeans needs at least one cluster");
        Self { k }
    }

    /// `cid c1 ... cd` → components.
    fn parse_state_line(line: &str) -> Option<(u64, Vec<i64>)> {
        let mut t = line.split_whitespace();
        let cid = t.next()?.parse().ok()?;
        let coords: Result<Vec<i64>, _> = t.map(str::parse).collect();
        coords.ok().map(|c| (cid, c))
    }

    /// Decode a state relation into `(cid, coords)` pairs — for display
    /// and assertions.
    pub fn centroids_from_state(state: &[String]) -> Vec<(u64, Vec<i64>)> {
        state.iter().filter_map(|l| Self::parse_state_line(l)).collect()
    }
}

impl IterativeWorkload for KMeans {
    type Step = KMeansStep;

    fn name(&self) -> &'static str {
        "kmeans"
    }

    /// `k` evenly spaced parseable points become the initial centroids
    /// (deterministic, scan order).
    fn init_state(&self, inputs: &JobInputs) -> Vec<String> {
        let points: Vec<Vec<i64>> = inputs.relations[KM_POINTS]
            .lines
            .iter()
            .filter_map(|line| parse_point(line))
            .collect();
        assert!(
            points.len() >= self.k,
            "kmeans: {} cluster(s) requested but only {} parseable point(s)",
            self.k,
            points.len()
        );
        (0..self.k)
            .map(|i| {
                let p = &points[i * points.len() / self.k];
                let coords: Vec<String> = p.iter().map(i64::to_string).collect();
                format!("{i} {}", coords.join(" "))
            })
            .collect()
    }

    fn step(&self, state: &[String]) -> Arc<KMeansStep> {
        let mut centroids = Self::centroids_from_state(state);
        centroids.sort_unstable_by_key(|(cid, _)| *cid);
        Arc::new(KMeansStep { centroids })
    }

    /// Move every centroid to its cluster mean (truncating integer
    /// division); empty clusters stay put. Delta is the max coordinate
    /// movement in grid units — 0 exactly at the fixed point.
    fn advance(&self, output: HashMap<u64, ClusterAcc>, state: &[String]) -> (Vec<String>, f64) {
        let mut delta = 0u64;
        let mut next = Vec::with_capacity(state.len());
        for line in state {
            let Some((cid, prev)) = Self::parse_state_line(line) else { continue };
            let new = match output.get(&cid) {
                Some(acc) if acc.count > 0 => (0..prev.len())
                    .map(|i| acc.sum.get(i).copied().unwrap_or(0) / acc.count as i64)
                    .collect(),
                _ => prev.clone(),
            };
            for (a, b) in prev.iter().zip(new.iter()) {
                delta = delta.max(a.abs_diff(*b));
            }
            let coords: Vec<String> = new.iter().map(i64::to_string).collect();
            next.push(format!("{cid} {}", coords.join(" ")));
        }
        (next, delta as f64)
    }
}

/// Synthesize `n` points in `dims` dimensions around `clusters` seeded
/// Gaussian-ish blobs (uniform noise, ±5% of the coordinate range), as
/// integer fixed-point lines for the k-means point relation.
pub fn synthesize_points(n: usize, dims: usize, clusters: usize, seed: u64) -> Vec<String> {
    assert!(dims > 0 && clusters > 0);
    let mut rng = Xoshiro256::new(seed);
    let centers: Vec<Vec<i64>> = (0..clusters)
        .map(|_| (0..dims).map(|_| rng.range_i64(-100_000, 100_000)).collect())
        .collect();
    (0..n)
        .map(|i| {
            let c = &centers[i % clusters];
            let coords: Vec<String> =
                c.iter().map(|&v| (v + rng.range_i64(-5_000, 5_000)).to_string()).collect();
            coords.join(" ")
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mapreduce::{run_iterative_serial, IterativeSpec};

    fn inputs(lines: Vec<String>) -> JobInputs {
        JobInputs::new().relation_lines("points", Arc::new(lines))
    }

    /// Two tight, far-apart blobs: k=2 must land one centroid on each.
    #[test]
    fn separates_two_obvious_clusters() {
        let mut lines = Vec::new();
        for d in [-2, -1, 0, 1, 2] {
            lines.push(format!("{} {}", 1_000 + d, 1_000 + d));
            lines.push(format!("{} {}", -1_000 + d, -1_000 + d));
        }
        let out = run_iterative_serial(
            &IterativeSpec::new(20).tolerance(0.0),
            &KMeans::new(2),
            &inputs(lines),
        );
        assert!(out.converged, "blobs this separated must reach the fixed point");
        let cents = KMeans::centroids_from_state(&out.state);
        assert_eq!(cents.len(), 2);
        let mut means: Vec<i64> = cents.iter().map(|(_, c)| c[0]).collect();
        means.sort_unstable();
        assert!((means[0] + 1_000).abs() <= 2, "{means:?}");
        assert!((means[1] - 1_000).abs() <= 2, "{means:?}");
    }

    #[test]
    fn fixed_point_is_exact_and_deterministic() {
        let pts = synthesize_points(200, 3, 4, 42);
        let it = IterativeSpec::new(25).tolerance(0.0);
        let a = run_iterative_serial(&it, &KMeans::new(4), &inputs(pts.clone()));
        let b = run_iterative_serial(&it, &KMeans::new(4), &inputs(pts));
        assert_eq!(a.state, b.state);
        if a.converged {
            assert_eq!(*a.deltas.last().unwrap(), 0.0, "exact fixed point");
        }
    }

    #[test]
    fn empty_cluster_keeps_its_centroid() {
        // Two identical points seed two identical centroids; the tie
        // always resolves to cid 0, so cluster 1 stays empty — and must
        // keep its coordinates instead of collapsing to 0/0.
        let lines = vec!["5 5".to_string(), "5 5".to_string()];
        let out = run_iterative_serial(
            &IterativeSpec::new(5).tolerance(0.0),
            &KMeans::new(2),
            &inputs(lines),
        );
        let cents = KMeans::centroids_from_state(&out.state);
        assert_eq!(cents.len(), 2);
        for (_, c) in &cents {
            assert_eq!(c, &vec![5, 5]);
        }
        assert!(out.converged);
    }

    #[test]
    fn cluster_acc_roundtrips_and_merges() {
        let a = ClusterAcc { count: 2, sum: vec![3, -4] };
        assert_eq!(ClusterAcc::from_bytes(&a.to_bytes()).unwrap(), a);
        assert!(a.heap_bytes() > 0);
        let mut acc = ClusterAcc::default();
        KMeansStep::combine(&mut acc, a);
        KMeansStep::combine(&mut acc, ClusterAcc { count: 1, sum: vec![1, 1, 1] });
        assert_eq!(acc, ClusterAcc { count: 3, sum: vec![4, -3, 1] });
    }

    #[test]
    fn nearest_breaks_ties_toward_smallest_cid() {
        let step = KMeansStep { centroids: vec![(0, vec![-10]), (1, vec![10])] };
        // 0 is equidistant: the smaller cid wins.
        assert_eq!(step.nearest(&[0]), Some(0));
        assert_eq!(step.nearest(&[6]), Some(1));
    }

    #[test]
    fn synthesize_is_deterministic_and_parseable() {
        let a = synthesize_points(50, 2, 3, 7);
        let b = synthesize_points(50, 2, 3, 7);
        assert_eq!(a, b);
        for line in &a {
            let coords: Result<Vec<i64>, _> = line.split_whitespace().map(str::parse).collect();
            assert_eq!(coords.unwrap().len(), 2);
        }
    }
}
