//! Grep — the filter-only, zero-shuffle workload.
//!
//! Map emits `(line id, line)` for lines containing the pattern; every key
//! is emitted exactly once, so there is nothing to co-locate and the
//! workload opts out of the exchange via [`Workload::needs_shuffle`].
//! Both engines then skip the shuffle entirely: results stay on the node
//! (Blaze) or in the map partition (Spark) that produced them, and
//! [`crate::mapreduce::JobReport::shuffle_bytes`] reads 0 — the `NetModel`
//! cost the paper's local-reduce argument is about simply disappears.
//! Set [`crate::mapreduce::JobSpec::force_shuffle()`] to run the exchange
//! anyway and measure what the skip saves.

use crate::mapreduce::Workload;

/// Emit every line containing `pattern` (plain substring match), keyed by
/// line id. Output is sorted by line id, so it is deterministic across
/// engines and cluster shapes.
#[derive(Clone, Debug)]
pub struct Grep {
    pub pattern: String,
}

impl Grep {
    pub fn new(pattern: impl Into<String>) -> Self {
        Self { pattern: pattern.into() }
    }
}

impl Workload for Grep {
    type Key = u64;
    type Value = String;
    type Output = Vec<(u64, String)>;

    fn name(&self) -> &'static str {
        "grep"
    }

    /// Keys are globally unique (one emission per matching line), so the
    /// engines may skip the exchange — the zero-shuffle fast path.
    fn needs_shuffle(&self) -> bool {
        false
    }

    fn map(&self, doc: u64, record: &str, emit: &mut dyn FnMut(u64, String)) {
        if record.contains(self.pattern.as_str()) {
            emit(doc, record.to_string());
        }
    }

    /// Unreachable: every key is emitted exactly once. (It must still be
    /// total — `force_shuffle` routes entries through the exchange, where
    /// distinct keys still never collide.)
    fn combine(acc: &mut String, v: String) {
        debug_assert!(*acc == v, "grep key collided: {acc:?} vs {v:?}");
        let _ = v;
    }

    fn finalize(&self, mut entries: Vec<(u64, String)>) -> Vec<(u64, String)> {
        entries.sort_unstable_by_key(|&(doc, _)| doc);
        entries
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::Corpus;
    use crate::mapreduce::run_serial;

    #[test]
    fn matches_are_sorted_by_line_id() {
        let corpus = Corpus::from_text("the cat\ndog\nthe end\ncat the\n");
        let out = run_serial(&Grep::new("the"), &corpus);
        assert_eq!(
            out,
            vec![
                (0, "the cat".to_string()),
                (2, "the end".to_string()),
                (3, "cat the".to_string()),
            ]
        );
    }

    #[test]
    fn no_matches_is_empty() {
        let corpus = Corpus::from_text("a\nb\n");
        assert!(run_serial(&Grep::new("zebra"), &corpus).is_empty());
    }

    #[test]
    fn empty_pattern_matches_every_line() {
        let corpus = Corpus::from_text("a\nb\n");
        assert_eq!(run_serial(&Grep::new(""), &corpus).len(), 2);
    }
}
