//! Concrete [`Workload`]s for the generic job layer — and the
//! **workload-authoring guide**.
//!
//! # The workload table
//!
//! Eleven workloads, chosen to exercise different corners of the
//! pipeline:
//!
//! * [`WordCount`] — the paper's job: `(word, 1)` with a sum reducer. The
//!   canonical string-keyed, alloc-sensitive case.
//! * [`InvertedIndex`] — word → sorted line-id postings: a non-numeric
//!   value type (`Vec<u32>`) with a concatenating reducer, so shuffle
//!   volume scales with *occurrences*, not distinct keys.
//! * [`TopKWords`] — word count with a bounded per-shard heap in
//!   `finalize_local`, so each node ships at most `k` candidates: the
//!   partial-reduce pattern.
//! * [`LengthHistogram`] — token-length → count over a dense, tiny integer
//!   key domain; the map pre-combines per record into a stack array, so
//!   emissions ≪ tokens.
//! * [`Join`] — inner equi-join of **two tagged input relations**,
//!   co-grouped by key: the multi-input pattern
//!   ([`Workload::num_relations`] + [`Workload::map_rel`]) with a custom
//!   shuffle value type ([`JoinSides`]) and a filtering `finalize_local`.
//! * [`DistinctCount`] — HyperLogLog-style register sketch: a **max**
//!   reducer and a `finalize` that genuinely merges (registers →
//!   cardinality estimate), the part of the contract nothing else touches.
//! * [`Grep`] — filter-only scan with globally unique keys: opts out of
//!   the exchange via [`Workload::needs_shuffle`], so both engines take
//!   the zero-shuffle fast path and report 0 shuffle bytes.
//! * [`PageRank`] — **iterative**: rank mass exchanged over a static edge
//!   relation, the fed-back state as a tagged relation, L1 convergence;
//!   all arithmetic in integer fixed-point so engines match the serial
//!   oracle bit-for-bit.
//! * [`KMeans`] — **iterative**: centroid assignment/update to an exact
//!   integer fixed point; the showcase for the partition cache (point
//!   parsing is skipped on warm rounds).
//! * [`Sessionize`] — **multi-stage** ([`mapreduce::ChainedWorkload`]):
//!   stage 1 groups timestamped log events into per-user sessions, stage
//!   2 aggregates session-length stats — two genuine shuffle boundaries,
//!   compiled into a two-stage `StageGraph` by the planner.
//! * [`Components`] — **iterative**: label-propagation connected
//!   components over an undirected edge relation; the first workload
//!   whose reducer is **min**, exactly convergent (round delta counts
//!   changed labels).
//!
//! Every workload is verified against [`mapreduce::run_serial`] (or
//! [`mapreduce::run_serial_inputs`] for the join,
//! [`mapreduce::run_chained_serial`] for the chained pipeline,
//! [`mapreduce::run_iterative_serial`] for the iterative set) on every
//! engine in `tests/integration_workloads.rs`,
//! `tests/integration_chained.rs` and `tests/integration_iterative.rs`,
//! including under injected failures.
//!
//! # Adding a workload
//!
//! 1. **Implement [`Workload`].** Pick `Key`/`Value` types that satisfy
//!    [`mapreduce::JobKey`]/[`mapreduce::JobValue`] (the built-in
//!    integers, `String`, `Vec<T>` and tuples already do; for a custom
//!    value type implement `Encode`/`Decode`/`HeapSize` yourself —
//!    [`JoinSides`] is the worked example). Single-input workloads
//!    implement `map`; multi-input workloads override `map_rel` and
//!    `num_relations` and stub `map` with a panic (engines only call
//!    `map_rel` — see [`Join`]). `combine` must be associative and
//!    commutative: engines fold in thread, cache, and shuffle arrival
//!    order — and since the real work-stealing executor
//!    ([`crate::runtime::Executor`]) landed, fold order also depends on
//!    *steal order*, which varies run to run at any `--threads` width
//!    above 1. An order-sensitive `combine` would be flaky, not just
//!    wrong on one engine; the thread-sweep parity grid
//!    (`tests/integration_spill.rs`) catches this at widths 1/2/4/8.
//! 2. **Respect the `finalize_local` contract.** Engines apply it
//!    independently to each owned shard, so it must be a *filtering
//!    partial reduce*: for any partition of the reduced entries into
//!    disjoint shards, `finalize(concat(map(finalize_local, shards)))`
//!    must equal `finalize(all entries)`. Identity (the default), bounded
//!    top-K selection ([`TopKWords`]), and per-key filters over complete
//!    groups ([`Join`]) all qualify; anything that mixes information
//!    *across* keys it then discards does not.
//! 3. **Make `finalize` deterministic.** Shuffle arrival order is not:
//!    sort postings/sides, or reduce to an order-free type, so the parity
//!    grid can use `assert_eq!`.
//! 4. **Implement [`StrWorkload`] if keys are `&str` slices of the
//!    record** (`map_str` must emit exactly what `map` emits, borrowed).
//!    This unlocks Blaze's zero-alloc "TCM" insert path and the Spark
//!    sim's UTF-16 `JvmWord` modeling — the paper's two headline
//!    mechanisms. Integer-keyed and multi-input workloads skip this.
//! 5. **Consider the fast paths.** If every key is emitted at most once
//!    globally (a pure filter like [`Grep`]), override
//!    [`Workload::needs_shuffle`] to `false` and the engines skip the
//!    exchange entirely. If a record can pre-combine its own emissions
//!    into a small dense structure ([`LengthHistogram`],
//!    [`DistinctCount`]), do it in `map` — emissions are the unit of
//!    engine work.
//! 6. **Wire it up:** a `--workload` arm in `main.rs`, a row in the
//!    parity grid in `tests/integration_workloads.rs` (with and without
//!    injected failures), and an entry in `benches/workloads.rs`.
//!
//! ## Spill (the storage hierarchy) and your workload
//!
//! Under `--spill-threshold` the engines run the **bounded-memory
//! exchange** ([`crate::storage::ExternalMerger`]): a reduce shard whose
//! in-flight bytes pass the budget is sorted by key and spilled to the
//! disk tier, and the shard your `finalize_local` receives comes back
//! from a loser-tree external merge. You get this for free — no workload
//! code changes — because the trait bounds already carry everything the
//! merger needs: keys are `Ord` (run sorting), keys and values are
//! `Encode`/`Decode` (run files) and `HeapSize` (the in-flight
//! estimate), and `combine` is associative + commutative (so merge
//! order, like shuffle-arrival order, cannot change the result). Two
//! consequences worth knowing:
//!
//! * the shard handed to `finalize_local` may arrive **key-sorted**
//!   (spill engaged) or in hash order (it didn't) — the
//!   filtering-partial-reduce contract already forbids depending on
//!   order, and the spill parity grid in `tests/integration_spill.rs`
//!   runs every workload both ways to enforce it;
//! * a [`mapreduce::CacheableWorkload`]'s `Parsed` type must implement
//!   `Encode`/`Decode` too — that is what lets cached parse blocks
//!   demote to the disk tier under `--cache-budget` pressure instead of
//!   being reparsed ([`PrParsed`] shows the tag-byte enum pattern).
//!
//! **Key cardinality shapes the data path.** Spill runs and shuffle
//! payloads dictionary-encode repeated keys (`--dict-keys`, see
//! [`crate::util::ser::DictWriter`]): each distinct key is written once
//! per run, repeats cost a varint back-reference. A Zipf-skewed string
//! domain like [`WordCount`]'s compresses dramatically — most key bytes
//! on the wire are repeats — while a near-unique domain (the doc-id-
//! tagged emissions of [`Sessionize`] stage 1, or [`Grep`]'s one-shot
//! keys) gains nothing and pays only the per-run dictionary's memory.
//! Dense integer keys ([`LengthHistogram`]) skip the dictionary
//! entirely — integers are their own wire form. The `dict keys` column
//! of the stage table (and `StageStats::dict`) shows per-stage savings,
//! so you can see which regime your workload lands in.
//!
//! # Writing an iterative workload
//!
//! An iterative job is a loop of step jobs with feedback:
//! [`mapreduce::run_iterative`] appends a line-rendered **state** relation
//! to your static inputs, runs one step job per round, and hands the
//! reduced output back to you to fold into the next state. To add one:
//!
//! 1. **Split the algorithm.** The per-round computation becomes a
//!    [`Workload`] (the *step*) that also implements
//!    [`mapreduce::CacheableWorkload`]: `parse_rel` is the pure,
//!    state-independent tokenization of a record (this is what the
//!    [`crate::cache::PartitionCache`] stores, so rounds after the first
//!    skip it), `map_parsed` is the per-round emission and may consult
//!    broadcast state carried on the step struct. The loop control —
//!    initial state, building each round's step with the previous state
//!    broadcast in, folding output → next state + convergence delta —
//!    becomes an [`mapreduce::IterativeWorkload`].
//! 2. **Stay on the integer grid.** Engines fold emissions in thread,
//!    cache, and shuffle-arrival order; float sums would differ in the
//!    last ulps per engine and cluster shape. Fixed-point integers make
//!    combine order-free, so the acceptance bar — final state
//!    bit-identical to [`mapreduce::run_iterative_serial`] on every
//!    engine — is meetable. [`PageRank`] ([`PR_SCALE`] units ≡ rank 1.0)
//!    and [`KMeans`] (integer coordinates, truncating mean) are the
//!    worked examples.
//! 3. **Make `advance` canonical.** Render the next state sorted by key
//!    and derive each round's state only from (previous state, reduced
//!    output); the driver compares states across engines with
//!    `assert_eq!`.
//! 4. **Keep the state relation self-describing.** Anything `advance` or
//!    the next round's mappers need (out-degrees, dimensions) must ride
//!    in the state lines — the state is a real shuffled relation, not a
//!    side channel.
//! 5. **Wire it up:** a `--workload` arm (plus `--iterations`,
//!    `--tolerance`, `--cache-budget` already exist), parity + failure
//!    rows in `tests/integration_iterative.rs`, and cached-vs-uncached
//!    rows in `benches/iterative.rs`.
//!
//! **Know your cache access pattern.** An iterative run is a *cyclic
//! scan*: every round sweeps the static relations' partitions once, in
//! order, while the fed-back state relation streams one-round-lived
//! generations through the same cache. When `--cache-budget` is below
//! the working set, plain LRU degenerates on exactly this pattern —
//! each sweep evicts what the next sweep is about to re-read, and the
//! hit-rate collapses toward zero. The scan-resistant policies
//! (`--cache-policy slru`, `gdsf`, or a `tinylfu` admission filter; see
//! [`crate::storage::policy`]) exist for this regime: they pin a stable
//! subset of the static partitions instead of churning all of them.
//! Policies only change *which* rounds re-parse — never the output
//! (parity under every policy is part of the acceptance grid). To
//! measure the effect on *your* workload, record a trace and replay it:
//! [`crate::mapreduce::JobSpec::trace`] + [`crate::storage::trace`], or
//! run `cargo bench --bench cache_policies`.
//!
//! # Writing a multi-stage workload
//!
//! A pipeline that needs more than one shuffle — sessionization, a
//! multi-pass aggregation — is a [`mapreduce::ChainedWorkload`]: a
//! sequence of ordinary [`Workload`]s in which stage N's reduced output,
//! rendered to canonical lines, becomes stage N+1's tagged input
//! relation. The planner compiles the chain into one
//! [`mapreduce::StageGraph`] (inspect it with
//! `blaze plan --workload <name>`); [`mapreduce::run_chained`] executes
//! it stage by stage through the engines' single plan path. To add one:
//!
//! 1. **Write each stage as a normal [`Workload`].** Stage 0 declares the
//!    chain's external relations; every later stage declares exactly one
//!    input relation — the bridge. Each stage may independently opt out
//!    of its exchange ([`Workload::needs_shuffle`]); the planner records
//!    the decision per stage (`Exchange::Elided` in the graph).
//! 2. **Render bridges canonically.** The renderer you pass to
//!    [`mapreduce::TypedStage::boxed`] turns a stage's finalized output
//!    into the next stage's lines. Sort by key and keep values integer:
//!    the bridge lines are the bit-identity surface the parity tests
//!    compare across engines (the chained analog of the iterative
//!    state-relation contract).
//! 3. **Keep bridge lines self-describing.** Anything a later stage
//!    needs must ride in the line — the bridge is a real relation fed to
//!    a real map phase, not a side channel ([`Sessionize`]'s
//!    `user start events duration` lines are the worked example).
//! 4. **Implement [`mapreduce::ChainedWorkload`]**: `name`,
//!    `num_relations` (stage 0's arity), and `stages()` returning the
//!    [`mapreduce::TypedStage`]-wrapped pipeline in order.
//! 5. **Wire it up:** a `--workload` arm in `main.rs`, parity + failure
//!    rows against [`mapreduce::run_chained_serial`] in
//!    `tests/integration_chained.rs`, a row in `benches/workloads.rs`
//!    (per-stage metrics come for free in
//!    [`mapreduce::ChainReport::stages`]), and a line in the `blaze plan`
//!    registry.
//!
//! **Reading a per-stage breakdown.** Two attribution views exist for a
//! multi-stage run. The `ChainReport` stage table (printed by the CLI
//! and `benches/workloads.rs`) reports each stage's **engine-side wall**
//! (map + exchange + per-shard finalize); driver-side work between
//! stages — rendering a stage's output and re-ingesting it as the next
//! stage's bridge relation — is measured separately as
//! [`mapreduce::ChainReport::bridge_secs`] (the `bridge` key in the
//! chain's detail), so stage walls plus bridge account for the job wall
//! instead of the bridge time silently vanishing between rows. For a
//! finer view, `blaze profile --workload <name>` attributes every traced
//! span (map/exchange/finalize/spill/task) to its containing stage and
//! prints per-phase wall vs busy (their ratio is the phase's effective
//! parallelism) plus the critical path — the phase sequence worth
//! optimizing. See the README's Observability section for the span
//! taxonomy.
//!
//! # Running under the service layer
//!
//! Workloads need nothing special to run multi-tenant: the service
//! ([`crate::service`]) isolates tenants entirely through the cache-key
//! scheme every workload already uses. Each tenant owns a contiguous
//! **namespace range** (`[(i+1)·2³², (i+2)·2³²)`, set on the spec via
//! [`crate::mapreduce::JobSpec::namespace_base`]) and each submitted
//! job offsets **generations** by `job_seq · 2²⁰`
//! ([`crate::mapreduce::JobSpec::generation_base`]) — iterative drivers bump
//! per-round generations inside that window, so no two jobs in one
//! shared [`crate::storage::TieredStore`] ever reuse a
//! `(namespace, generation)` pair. The only contract a workload author
//! inherits: derive cache keys from the spec's bases (the engines and
//! drivers already do), never from hard-coded namespaces. If your
//! workload caches aggressively, note that a tenant over its
//! `--tenant-quota` has inserts demoted to disk at birth — correctness
//! is unaffected (the catalog's oracle checks run under quotas in
//! `tests/integration_service.rs`), only locality.
//!
//! [`mapreduce::run_serial`]: crate::mapreduce::run_serial
//! [`mapreduce::run_serial_inputs`]: crate::mapreduce::run_serial_inputs
//! [`mapreduce::run_iterative_serial`]: crate::mapreduce::run_iterative_serial
//! [`mapreduce::run_iterative`]: crate::mapreduce::run_iterative
//! [`mapreduce::run_chained`]: crate::mapreduce::run_chained
//! [`mapreduce::run_chained_serial`]: crate::mapreduce::run_chained_serial
//! [`mapreduce::CacheableWorkload`]: crate::mapreduce::CacheableWorkload
//! [`mapreduce::IterativeWorkload`]: crate::mapreduce::IterativeWorkload
//! [`mapreduce::ChainedWorkload`]: crate::mapreduce::ChainedWorkload
//! [`mapreduce::ChainReport::stages`]: crate::mapreduce::ChainReport::stages
//! [`mapreduce::ChainReport::bridge_secs`]: crate::mapreduce::ChainReport::bridge_secs
//! [`mapreduce::StageGraph`]: crate::mapreduce::StageGraph
//! [`mapreduce::TypedStage`]: crate::mapreduce::TypedStage
//! [`mapreduce::TypedStage::boxed`]: crate::mapreduce::TypedStage::boxed
//! [`mapreduce::JobKey`]: crate::mapreduce::JobKey
//! [`mapreduce::JobValue`]: crate::mapreduce::JobValue

mod components;
mod distinct;
mod grep;
mod join;
mod kmeans;
mod pagerank;
mod sessionize;

pub use components::{CcParsed, Components, ComponentsStep, CC_EDGES, CC_STATE};
pub use distinct::{DistinctCount, REGISTERS};
pub use grep::Grep;
pub use join::{Join, JoinSides, LEFT, RIGHT};
pub use kmeans::{synthesize_points, ClusterAcc, KMeans, KMeansStep, KmParsed, KM_POINTS, KM_STATE};
pub use pagerank::{PageRank, PageRankStep, PrParsed, PR_EDGES, PR_SCALE, PR_STATE};
pub use sessionize::{synthesize_logs, SessionAssembly, SessionStats, Sessionize};

use std::collections::HashMap;

use crate::corpus::Tokenizer;
use crate::mapreduce::{StrWorkload, Workload};

#[cfg(test)]
use crate::mapreduce::run_serial;

// ------------------------------------------------------------ wordcount ----

/// The paper's workload: count word occurrences.
#[derive(Clone, Copy, Debug)]
pub struct WordCount {
    pub tokenizer: Tokenizer,
}

impl WordCount {
    pub fn new(tokenizer: Tokenizer) -> Self {
        Self { tokenizer }
    }
}

impl Workload for WordCount {
    type Key = String;
    type Value = u64;
    type Output = HashMap<String, u64>;

    fn name(&self) -> &'static str {
        "wordcount"
    }

    fn map(&self, _doc: u64, record: &str, emit: &mut dyn FnMut(String, u64)) {
        self.tokenizer.for_each_token(record, |t| emit(t.to_string(), 1));
    }

    fn combine(acc: &mut u64, v: u64) {
        *acc += v;
    }

    fn finalize(&self, entries: Vec<(String, u64)>) -> HashMap<String, u64> {
        entries.into_iter().collect()
    }
}

impl StrWorkload for WordCount {
    fn map_str(&self, _doc: u64, record: &str, emit: &mut dyn FnMut(&str, u64)) {
        self.tokenizer.for_each_token(record, |t| emit(t, 1));
    }
}

// ------------------------------------------------------- inverted index ----

/// Word → sorted, deduplicated list of line ids containing it.
#[derive(Clone, Copy, Debug)]
pub struct InvertedIndex {
    pub tokenizer: Tokenizer,
}

impl InvertedIndex {
    pub fn new(tokenizer: Tokenizer) -> Self {
        Self { tokenizer }
    }
}

impl Workload for InvertedIndex {
    type Key = String;
    type Value = Vec<u32>;
    type Output = HashMap<String, Vec<u32>>;

    fn name(&self) -> &'static str {
        "index"
    }

    fn map(&self, doc: u64, record: &str, emit: &mut dyn FnMut(String, Vec<u32>)) {
        self.tokenizer.for_each_token(record, |t| emit(t.to_string(), vec![doc as u32]));
    }

    fn combine(acc: &mut Vec<u32>, mut v: Vec<u32>) {
        acc.append(&mut v);
    }

    /// Postings arrive in shuffle order; sort + dedup makes the index
    /// deterministic across engines and cluster shapes.
    fn finalize(&self, entries: Vec<(String, Vec<u32>)>) -> HashMap<String, Vec<u32>> {
        entries
            .into_iter()
            .map(|(k, mut postings)| {
                postings.sort_unstable();
                postings.dedup();
                (k, postings)
            })
            .collect()
    }
}

impl StrWorkload for InvertedIndex {
    fn map_str(&self, doc: u64, record: &str, emit: &mut dyn FnMut(&str, Vec<u32>)) {
        self.tokenizer.for_each_token(record, |t| emit(t, vec![doc as u32]));
    }
}

// ---------------------------------------------------------- top-K words ----

/// The `k` most frequent words (count desc, ties broken alphabetically).
///
/// The interesting part is `finalize_local`: each shard keeps only its own
/// top `k` via a bounded min-heap, so a node ships `O(k)` candidates
/// instead of its whole vocabulary shard. Because shards partition the key
/// space, the union of per-shard top-`k` sets always contains the global
/// top `k` — the partial reduce is exact.
#[derive(Clone, Copy, Debug)]
pub struct TopKWords {
    pub tokenizer: Tokenizer,
    pub k: usize,
}

impl TopKWords {
    pub fn new(tokenizer: Tokenizer, k: usize) -> Self {
        Self { tokenizer, k }
    }
}

impl Workload for TopKWords {
    type Key = String;
    type Value = u64;
    type Output = Vec<(String, u64)>;

    fn name(&self) -> &'static str {
        "top-k"
    }

    fn map(&self, _doc: u64, record: &str, emit: &mut dyn FnMut(String, u64)) {
        self.tokenizer.for_each_token(record, |t| emit(t.to_string(), 1));
    }

    fn combine(acc: &mut u64, v: u64) {
        *acc += v;
    }

    fn finalize_local(&self, shard: Vec<(String, u64)>) -> Vec<(String, u64)> {
        select_top_k(shard, self.k)
    }

    fn finalize(&self, entries: Vec<(String, u64)>) -> Vec<(String, u64)> {
        select_top_k(entries, self.k)
    }
}

impl StrWorkload for TopKWords {
    fn map_str(&self, _doc: u64, record: &str, emit: &mut dyn FnMut(&str, u64)) {
        self.tokenizer.for_each_token(record, |t| emit(t, 1));
    }
}

/// Keep the `k` best entries by (count desc, then word asc) with a bounded
/// min-heap: the heap top is always the worst kept candidate. `O(n log k)`
/// and `O(k)` memory — the per-node heap the shuffle saving comes from.
fn select_top_k(entries: Vec<(String, u64)>, k: usize) -> Vec<(String, u64)> {
    use std::cmp::Reverse;
    use std::collections::BinaryHeap;
    if k == 0 {
        return Vec::new();
    }
    // Rank = (count, Reverse(word)): larger is better, so the Reverse
    // wrapper turns BinaryHeap's max-heap into a min-heap over ranks.
    let mut heap: BinaryHeap<Reverse<(u64, Reverse<String>)>> = BinaryHeap::with_capacity(k + 1);
    for (word, count) in entries {
        heap.push(Reverse((count, Reverse(word))));
        if heap.len() > k {
            heap.pop();
        }
    }
    let mut out: Vec<(String, u64)> =
        heap.into_iter().map(|Reverse((count, Reverse(word)))| (word, count)).collect();
    out.sort_by(|a, b| b.1.cmp(&a.1).then(a.0.cmp(&b.0)));
    out
}

// ------------------------------------------------------ length histogram ----

/// Token-length (in chars) → token count.
///
/// The dense small-key case: lengths under [`DENSE_LENGTHS`] accumulate in
/// a per-record stack array and are emitted once per distinct length, so
/// the engines see a tiny key domain and far fewer emissions than tokens.
#[derive(Clone, Copy, Debug)]
pub struct LengthHistogram {
    pub tokenizer: Tokenizer,
}

/// Dense fast-path width: tokens longer than this are emitted directly
/// (natural-language tokens essentially never are).
pub const DENSE_LENGTHS: usize = 33;

impl LengthHistogram {
    pub fn new(tokenizer: Tokenizer) -> Self {
        Self { tokenizer }
    }
}

impl Workload for LengthHistogram {
    type Key = u32;
    type Value = u64;
    type Output = Vec<(u32, u64)>;

    fn name(&self) -> &'static str {
        "length-hist"
    }

    fn map(&self, _doc: u64, record: &str, emit: &mut dyn FnMut(u32, u64)) {
        let mut dense = [0u64; DENSE_LENGTHS];
        self.tokenizer.for_each_token(record, |t| {
            let len = t.chars().count();
            if len < DENSE_LENGTHS {
                dense[len] += 1;
            } else {
                emit(len as u32, 1);
            }
        });
        for (len, &n) in dense.iter().enumerate() {
            if n > 0 {
                emit(len as u32, n);
            }
        }
    }

    fn combine(acc: &mut u64, v: u64) {
        *acc += v;
    }

    /// Sorted by length, for stable display and comparison.
    fn finalize(&self, mut entries: Vec<(u32, u64)>) -> Vec<(u32, u64)> {
        entries.sort_unstable();
        entries
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::Corpus;

    fn tiny() -> Corpus {
        Corpus::from_text("the cat sat\nthe cat\nthe end here\n")
    }

    #[test]
    fn wordcount_serial() {
        let out = run_serial(&WordCount::new(Tokenizer::Spaces), &tiny());
        assert_eq!(out.get("the"), Some(&3));
        assert_eq!(out.get("cat"), Some(&2));
        assert_eq!(out.get("here"), Some(&1));
        assert_eq!(out.len(), 5);
    }

    #[test]
    fn inverted_index_serial() {
        let out = run_serial(&InvertedIndex::new(Tokenizer::Spaces), &tiny());
        assert_eq!(out["the"], vec![0, 1, 2]);
        assert_eq!(out["cat"], vec![0, 1]);
        assert_eq!(out["sat"], vec![0]);
        assert_eq!(out["end"], vec![2]);
    }

    #[test]
    fn index_dedups_repeats_within_line() {
        let corpus = Corpus::from_text("a a b\nb a\n");
        let out = run_serial(&InvertedIndex::new(Tokenizer::Spaces), &corpus);
        assert_eq!(out["a"], vec![0, 1]);
        assert_eq!(out["b"], vec![0, 1]);
    }

    #[test]
    fn top_k_serial_ordering() {
        let out = run_serial(&TopKWords::new(Tokenizer::Spaces, 2), &tiny());
        assert_eq!(out, vec![("the".to_string(), 3), ("cat".to_string(), 2)]);
    }

    #[test]
    fn top_k_tie_break_is_alphabetical() {
        let corpus = Corpus::from_text("b a c\nb a c\n");
        let out = run_serial(&TopKWords::new(Tokenizer::Spaces, 2), &corpus);
        assert_eq!(out, vec![("a".to_string(), 2), ("b".to_string(), 2)]);
    }

    #[test]
    fn select_top_k_bounds() {
        assert!(select_top_k(vec![("x".into(), 1)], 0).is_empty());
        let few = select_top_k(vec![("x".into(), 1), ("y".into(), 9)], 5);
        assert_eq!(few, vec![("y".to_string(), 9), ("x".to_string(), 1)]);
    }

    #[test]
    fn length_histogram_serial() {
        let out = run_serial(&LengthHistogram::new(Tokenizer::Spaces), &tiny());
        // tokens: the cat sat the cat the end here → 3×7 letters of len 3, 1 of len 4
        assert_eq!(out, vec![(3, 7), (4, 1)]);
    }

    #[test]
    fn length_histogram_handles_long_tokens() {
        let long = "x".repeat(50);
        let corpus = Corpus::from_text(&format!("{long} {long} ok\n"));
        let out = run_serial(&LengthHistogram::new(Tokenizer::Spaces), &corpus);
        assert_eq!(out, vec![(2, 1), (50, 2)]);
    }

    #[test]
    fn str_and_owned_maps_agree() {
        // map_str must emit exactly what map emits, for every StrWorkload.
        let corpus = Corpus::from_text("the cat the\nhat\n");
        let wc = WordCount::new(Tokenizer::Spaces);
        let mut owned = Vec::new();
        let mut borrowed = Vec::new();
        for (i, line) in corpus.lines.iter().enumerate() {
            wc.map(i as u64, line, &mut |k, v| owned.push((k, v)));
            wc.map_str(i as u64, line, &mut |k, v| borrowed.push((k.to_string(), v)));
        }
        assert_eq!(owned, borrowed);
    }
}
