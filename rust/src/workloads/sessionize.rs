//! Sessionization — the first genuinely **multi-stage** workload: two
//! shuffles, chained through the planner layer's bridge relation.
//!
//! Input shape: each log line is `user ts` (first token the user id,
//! second an integer timestamp; trailing tokens — payloads, URLs — are
//! ignored, malformed lines dropped). The pipeline:
//!
//! * **stage 1** ([`SessionAssembly`], shuffle keyed by user): co-locate
//!   every timestamp of a user, then split the sorted timestamps into
//!   sessions wherever the gap between consecutive events exceeds
//!   [`Sessionize::gap`]. The stage's reduced output renders to one
//!   bridge line per session: `user start_ts events duration`, sorted by
//!   (user, start).
//! * **stage 2** ([`SessionStats`], shuffle keyed by session length):
//!   aggregate the session relation into a histogram — for each
//!   events-per-session count, how many sessions and how much total
//!   duration. Final lines: `events sessions total_duration`, sorted by
//!   events.
//!
//! Neither stage alone can express this: stage 2's keys (session lengths)
//! only exist after stage 1's per-user grouping, so the job needs two
//! exchange boundaries — exactly what [`ChainedWorkload`] compiles to a
//! two-stage [`StageGraph`](crate::mapreduce::StageGraph). All arithmetic
//! is integer (timestamps, counts, durations), so both engines match
//! [`run_chained_serial`](crate::mapreduce::run_chained_serial)
//! bit-identically on the rendered lines.

use std::sync::Arc;

use crate::mapreduce::{ChainStage, ChainedWorkload, TypedStage, Workload};
use crate::util::rng::Xoshiro256;

/// Stage 1: group event timestamps per user (the session-assembly
/// shuffle). Values are timestamp lists with a concatenating reducer —
/// order restored deterministically in `finalize`.
#[derive(Clone, Copy, Debug)]
pub struct SessionAssembly;

impl Workload for SessionAssembly {
    type Key = String;
    type Value = Vec<u64>;
    type Output = Vec<(String, Vec<u64>)>;

    fn name(&self) -> &'static str {
        "sessions"
    }

    /// `user ts ...` → `(user, [ts])`; malformed lines emit nothing.
    fn map(&self, _doc: u64, record: &str, emit: &mut dyn FnMut(String, Vec<u64>)) {
        let mut toks = record.split_whitespace();
        let Some(user) = toks.next() else { return };
        let Some(ts) = toks.next().and_then(|t| t.parse::<u64>().ok()) else { return };
        emit(user.to_string(), vec![ts]);
    }

    fn combine(acc: &mut Vec<u64>, mut v: Vec<u64>) {
        acc.append(&mut v);
    }

    /// Timestamps arrive in shuffle order; sort both layers so the bridge
    /// rendering is canonical.
    fn finalize(&self, mut entries: Vec<(String, Vec<u64>)>) -> Vec<(String, Vec<u64>)> {
        for (_, tss) in entries.iter_mut() {
            tss.sort_unstable();
        }
        entries.sort_by(|a, b| a.0.cmp(&b.0));
        entries
    }
}

/// Render stage 1's reduced output into the bridge relation: one line per
/// session, `user start_ts events duration`, split wherever the gap
/// between consecutive events exceeds `gap`.
fn render_sessions(users: Vec<(String, Vec<u64>)>, gap: u64) -> Vec<String> {
    let mut lines = Vec::new();
    for (user, tss) in users {
        let mut it = tss.into_iter();
        let Some(first) = it.next() else { continue };
        let (mut start, mut prev, mut events) = (first, first, 1u64);
        for ts in it {
            if ts - prev > gap {
                lines.push(format!("{user} {start} {events} {}", prev - start));
                start = ts;
                events = 0;
            }
            prev = ts;
            events += 1;
        }
        lines.push(format!("{user} {start} {events} {}", prev - start));
    }
    lines
}

/// Stage 2: aggregate the session relation into per-length statistics.
/// Key = events per session; value = (session count, total duration).
#[derive(Clone, Copy, Debug)]
pub struct SessionStats;

impl Workload for SessionStats {
    type Key = u64;
    type Value = (u64, u64);
    type Output = Vec<(u64, (u64, u64))>;

    fn name(&self) -> &'static str {
        "session-stats"
    }

    /// `user start events duration` → `(events, (1, duration))`.
    fn map(&self, _doc: u64, record: &str, emit: &mut dyn FnMut(u64, (u64, u64))) {
        let mut toks = record.split_whitespace();
        let (Some(_user), Some(_start)) = (toks.next(), toks.next()) else { return };
        let Some(events) = toks.next().and_then(|t| t.parse::<u64>().ok()) else { return };
        let Some(duration) = toks.next().and_then(|t| t.parse::<u64>().ok()) else { return };
        emit(events, (1, duration));
    }

    fn combine(acc: &mut (u64, u64), v: (u64, u64)) {
        acc.0 += v.0;
        acc.1 += v.1;
    }

    fn finalize(&self, mut entries: Vec<(u64, (u64, u64))>) -> Vec<(u64, (u64, u64))> {
        entries.sort_unstable();
        entries
    }
}

fn render_stats(stats: Vec<(u64, (u64, u64))>) -> Vec<String> {
    stats
        .into_iter()
        .map(|(events, (sessions, total_dur))| format!("{events} {sessions} {total_dur}"))
        .collect()
}

/// The chained pipeline: session assembly, then session-length stats.
#[derive(Clone, Copy, Debug)]
pub struct Sessionize {
    /// Maximum intra-session gap (timestamp units): a larger gap between
    /// consecutive events of a user starts a new session.
    pub gap: u64,
}

impl Sessionize {
    pub fn new(gap: u64) -> Self {
        Self { gap }
    }

    /// Decode the final lines into `(events, sessions, total_duration)`
    /// rows — for display and assertions.
    pub fn stats_from_lines(lines: &[String]) -> Vec<(u64, u64, u64)> {
        lines
            .iter()
            .filter_map(|l| {
                let mut t = l.split_whitespace();
                Some((t.next()?.parse().ok()?, t.next()?.parse().ok()?, t.next()?.parse().ok()?))
            })
            .collect()
    }
}

impl ChainedWorkload for Sessionize {
    fn name(&self) -> &'static str {
        "sessionize"
    }

    fn stages(&self) -> Vec<Box<dyn ChainStage>> {
        let gap = self.gap;
        vec![
            TypedStage::boxed(Arc::new(SessionAssembly), move |out| render_sessions(out, gap)),
            TypedStage::boxed(Arc::new(SessionStats), render_stats),
        ]
    }
}

/// Synthesize a shuffled event log for `users` users and `events` total
/// events: each user walks a clock forward with mostly-small steps and
/// occasional jumps well past `gap`, so sessionization at that gap yields
/// a non-trivial mix of session lengths. Deterministic in `seed`.
pub fn synthesize_logs(users: usize, events: usize, gap: u64, seed: u64) -> Vec<String> {
    assert!(users > 0, "need at least one user");
    let mut rng = Xoshiro256::new(seed);
    let mut clocks: Vec<u64> = (0..users).map(|_| rng.next_below(gap.max(1))).collect();
    let mut lines = Vec::with_capacity(events);
    for _ in 0..events {
        let u = rng.index(users);
        clocks[u] += if rng.chance(0.2) {
            // Session break: jump well past the gap.
            gap + 1 + rng.next_below(gap.max(1) * 3 + 1)
        } else {
            rng.next_below(gap.max(1))
        };
        lines.push(format!("u{u} {}", clocks[u]));
    }
    rng.shuffle(&mut lines);
    lines
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mapreduce::{run_chained_serial, JobInputs};

    fn log_inputs(lines: &[&str]) -> JobInputs {
        JobInputs::new().relation_lines(
            "logs",
            Arc::new(lines.iter().map(|s| s.to_string()).collect()),
        )
    }

    #[test]
    fn sessions_split_on_gap() {
        // u1: events at 0, 5, 100 with gap 10 → sessions [0,5] and [100].
        let inputs = log_inputs(&["u1 0", "u1 100", "u1 5"]);
        let lines = run_chained_serial(&Sessionize::new(10), &inputs);
        // One 2-event session of duration 5, one 1-event session.
        assert_eq!(lines, vec!["1 1 0".to_string(), "2 1 5".to_string()]);
    }

    #[test]
    fn bridge_lines_are_sorted_and_deterministic() {
        let inputs = log_inputs(&["b 3", "a 1", "a 2", "b 50", "a 40"]);
        let sz = Sessionize::new(10);
        let a = run_chained_serial(&sz, &inputs);
        let b = run_chained_serial(&sz, &inputs);
        assert_eq!(a, b);
        let stats = Sessionize::stats_from_lines(&a);
        // Sessions: a:[1,2], a:[40], b:[3], b:[50] → two 1-event, one
        // 2-event.
        assert_eq!(stats, vec![(1, 3, 0), (2, 1, 1)]);
    }

    #[test]
    fn malformed_lines_are_dropped() {
        let inputs = log_inputs(&["", "useronly", "u1 notanumber", "u1 7"]);
        let lines = run_chained_serial(&Sessionize::new(10), &inputs);
        assert_eq!(lines, vec!["1 1 0".to_string()]);
    }

    #[test]
    fn empty_log_has_empty_stats() {
        let inputs = log_inputs(&[]);
        assert!(run_chained_serial(&Sessionize::new(10), &inputs).is_empty());
    }

    #[test]
    fn synthesized_logs_have_session_mix() {
        let logs = synthesize_logs(8, 500, 100, 42);
        assert_eq!(logs.len(), 500);
        let inputs =
            JobInputs::new().relation_lines("logs", Arc::new(logs));
        let lines = run_chained_serial(&Sessionize::new(100), &inputs);
        let stats = Sessionize::stats_from_lines(&lines);
        assert!(!stats.is_empty());
        // Session breaks happen (~20% of steps), so there must be more
        // sessions than users and more than one session length.
        let sessions: u64 = stats.iter().map(|(_, n, _)| n).sum();
        assert!(sessions > 8, "expected multiple sessions per user, got {sessions}");
        assert!(stats.len() > 1, "expected a mix of session lengths: {stats:?}");
        // Every event lands in exactly one session.
        let events: u64 = stats.iter().map(|(len, n, _)| len * n).sum();
        assert_eq!(events, 500);
    }
}
