//! Approximate distinct-token count via a HyperLogLog-style register
//! sketch — the workload whose `finalize` genuinely *computes* something.
//!
//! Every token hashes to one of [`REGISTERS`] registers (top bits of the
//! hash) carrying the rank of the remaining bits (leading zeros + 1); the
//! reducer keeps the per-register **max**. The register file is tiny and
//! fixed-size, so shuffle volume is O(registers) per node no matter how
//! large the corpus — the sketch property that makes cardinality counting
//! cheap on a cluster. The driver-side [`Workload::finalize`] then merges
//! the registers into the harmonic-mean estimate (with the standard
//! linear-counting correction for small cardinalities). Every step is
//! deterministic, so the engines' estimates are bit-identical to
//! [`crate::mapreduce::run_serial`]'s — the parity grid still applies even
//! though the *estimate* is approximate.

use crate::corpus::Tokenizer;
use crate::hash::HashKind;
use crate::mapreduce::Workload;

/// Number of sketch registers (2^8; the top 8 hash bits pick one).
pub const REGISTERS: usize = 256;

/// Approximate distinct-token count (HyperLogLog-style).
#[derive(Clone, Copy, Debug)]
pub struct DistinctCount {
    pub tokenizer: Tokenizer,
}

impl DistinctCount {
    pub fn new(tokenizer: Tokenizer) -> Self {
        Self { tokenizer }
    }

    /// (register, rank) of one token: register = top 8 hash bits, rank =
    /// leading zeros of the remaining 56 bits + 1 (∈ [1, 57]).
    fn sketch(token: &str) -> (u32, u8) {
        let h = HashKind::Wy.hash(token.as_bytes());
        let reg = (h >> 56) as u32;
        let rest = h << 8;
        let rank = (rest.leading_zeros().min(56) + 1) as u8;
        (reg, rank)
    }
}

impl Workload for DistinctCount {
    type Key = u32;
    type Value = u8;
    type Output = u64;

    fn name(&self) -> &'static str {
        "distinct"
    }

    /// Per-record dense pre-combine (cf. `LengthHistogram`): a record's
    /// tokens fold into a stack register file first, so emissions per
    /// record are bounded by distinct registers hit, not token count.
    fn map(&self, _doc: u64, record: &str, emit: &mut dyn FnMut(u32, u8)) {
        let mut regs = [0u8; REGISTERS];
        self.tokenizer.for_each_token(record, |t| {
            let (reg, rank) = Self::sketch(t);
            if rank > regs[reg as usize] {
                regs[reg as usize] = rank;
            }
        });
        for (reg, &rank) in regs.iter().enumerate() {
            if rank > 0 {
                emit(reg as u32, rank);
            }
        }
    }

    /// Register merge is **max**, not sum — the sketch's whole trick.
    fn combine(acc: &mut u8, v: u8) {
        if v > *acc {
            *acc = v;
        }
    }

    /// Merge the register file into the cardinality estimate: harmonic
    /// mean of `2^-rank` over all registers, bias-corrected, with linear
    /// counting when most registers are still empty.
    ///
    /// The harmonic sum is accumulated in exact fixed-point (units of
    /// `2^-57`, the smallest register contribution) rather than floating
    /// point: f64 addition is order-dependent, and entries arrive in
    /// shuffle order — exactness is what keeps every engine's estimate
    /// bit-identical to the serial oracle's.
    fn finalize(&self, entries: Vec<(u32, u8)>) -> u64 {
        let m = REGISTERS as f64;
        let mut fixed: u128 = 0; // Σ 2^-rank, in units of 2^-57
        let mut zeros = REGISTERS as u32;
        for &(reg, rank) in &entries {
            debug_assert!((reg as usize) < REGISTERS && (1..=57).contains(&rank));
            fixed += 1u128 << (57 - rank.min(57));
            zeros -= 1;
        }
        fixed += (zeros as u128) << 57; // empty registers contribute 2^0
        let sum = fixed as f64 / (1u128 << 57) as f64;
        let alpha = 0.7213 / (1.0 + 1.079 / m);
        let raw = alpha * m * m / sum;
        let estimate = if raw <= 2.5 * m && zeros > 0 {
            m * (m / zeros as f64).ln() // linear counting regime
        } else {
            raw
        };
        estimate.round() as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::Corpus;
    use crate::mapreduce::run_serial;
    use std::collections::HashSet;

    fn exact_distinct(corpus: &Corpus, tokenizer: Tokenizer) -> u64 {
        let mut seen: HashSet<String> = HashSet::new();
        for line in &corpus.lines {
            tokenizer.for_each_token(line, |t| {
                seen.insert(t.to_string());
            });
        }
        seen.len() as u64
    }

    #[test]
    fn empty_corpus_counts_zero() {
        let est = run_serial(&DistinctCount::new(Tokenizer::Spaces), &Corpus::from_text(""));
        assert_eq!(est, 0);
    }

    #[test]
    fn tiny_cardinalities_are_exactish() {
        // Linear counting makes single-digit cardinalities near-exact.
        let corpus = Corpus::from_text("a b c a b a\nc a\n");
        let est = run_serial(&DistinctCount::new(Tokenizer::Spaces), &corpus);
        assert_eq!(est, 3);
    }

    #[test]
    fn estimate_tracks_exact_count_within_sketch_error() {
        // 5000 distinct tokens, each appearing twice. 256 registers give
        // ~6.5% standard error; this fixed draw lands at -2.9%.
        let text: String = (0..1000)
            .map(|line| {
                let words: Vec<String> =
                    (0..5).map(|w| format!("w{}", (line * 5 + w) % 5000)).collect();
                words.join(" ") + "\n"
            })
            .collect::<String>();
        let corpus = Corpus::from_text(&text.repeat(2));
        assert_eq!(exact_distinct(&corpus, Tokenizer::Spaces), 5000);
        let est = run_serial(&DistinctCount::new(Tokenizer::Spaces), &corpus) as f64;
        let rel_err = (est - 5000.0).abs() / 5000.0;
        assert!(rel_err < 0.10, "estimate {est} vs exact 5000: rel err {rel_err:.3}");
    }

    #[test]
    fn rank_is_bounded_and_deterministic() {
        for t in ["a", "the", "zzzz", ""] {
            let (reg, rank) = DistinctCount::sketch(t);
            assert!((reg as usize) < REGISTERS);
            assert!((1..=57).contains(&rank));
            assert_eq!(DistinctCount::sketch(t), (reg, rank));
        }
    }
}
