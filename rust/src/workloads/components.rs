//! Connected components — label propagation on the iterative driver: the
//! third iterative workload, and the first whose per-round reducer is
//! **min** rather than a sum.
//!
//! Input shape: each line of the (static) edge relation is an adjacency
//! fragment `u v1 v2 ...` — undirected edges `{u, v<i>}`; a node's
//! adjacency may be split across any number of lines. The fed-back state
//! relation holds one line per node: `node label`.
//!
//! # Round structure
//!
//! * `init_state`: every node (source or neighbor) gets a distinct
//!   integer label — its index in sorted node order;
//! * map over an edge fragment: for every edge `{u, v}`, push each
//!   endpoint's current (broadcast) label at the other —
//!   `(u, label(v))` and `(v, label(u))`;
//! * map over a state line: emit `(node, own label)` so isolated-in-round
//!   nodes survive;
//! * combine: **min** — order-free, so engines match the serial oracle
//!   bit-identically on any cluster shape;
//! * `advance`: `new = min(old, inflow)`; the round delta is the number
//!   of nodes whose label changed, so `delta == 0` (under any tolerance)
//!   is exact convergence.
//!
//! At the fixed point every node carries the minimum initial label of its
//! component; labels partition the graph into its connected components.
//! Edge parsing is the cacheable half ([`CacheableWorkload`]): the edge
//! relation never changes across rounds, so warm rounds skip
//! tokenization. Convergence takes at most `diameter` rounds — label
//! propagation's usual bound.

use std::collections::{BTreeSet, HashMap};
use std::sync::Arc;

use crate::mapreduce::{CacheableWorkload, IterativeWorkload, JobInputs, Workload};
use crate::storage::HeapSize;
use crate::util::ser::{Decode, DecodeError, Encode, Reader};

/// Relation index of the static edge relation.
pub const CC_EDGES: usize = 0;
/// Relation index of the fed-back state relation.
pub const CC_STATE: usize = 1;

/// Parsed form of one record — what the partition cache stores per split.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum CcParsed {
    /// One adjacency fragment of the edge relation.
    Edges { src: String, dsts: Vec<String> },
    /// One `node label` line of the state relation.
    Node(String, u64),
}

impl HeapSize for CcParsed {
    fn heap_bytes(&self) -> usize {
        match self {
            CcParsed::Edges { src, dsts } => src.heap_bytes() + dsts.heap_bytes() + 16,
            CcParsed::Node(n, _) => n.heap_bytes() + 24,
        }
    }
}

// Wire form (tag byte + fields) so cached parse blocks can demote to the
// disk tier under memory pressure.
impl Encode for CcParsed {
    fn encode(&self, out: &mut Vec<u8>) {
        match self {
            CcParsed::Edges { src, dsts } => {
                out.push(0);
                src.encode(out);
                dsts.encode(out);
            }
            CcParsed::Node(node, label) => {
                out.push(1);
                node.encode(out);
                label.encode(out);
            }
        }
    }
}

impl Decode for CcParsed {
    fn decode(r: &mut Reader<'_>) -> Result<Self, DecodeError> {
        match u8::decode(r)? {
            0 => Ok(CcParsed::Edges { src: String::decode(r)?, dsts: Vec::decode(r)? }),
            1 => Ok(CcParsed::Node(String::decode(r)?, u64::decode(r)?)),
            t => Err(DecodeError::BadTag(t)),
        }
    }
}

/// One round of label propagation, with the previous labels broadcast in
/// (built fresh each round by `Components::step`).
pub struct ComponentsStep {
    /// node → label of the previous round.
    labels: HashMap<String, u64>,
}

impl Workload for ComponentsStep {
    type Key = String;
    type Value = u64;
    type Output = HashMap<String, u64>;

    fn name(&self) -> &'static str {
        "components"
    }

    fn num_relations(&self) -> usize {
        2
    }

    /// Multi-input stub: engines and oracles route through `map_rel`.
    fn map(&self, _doc: u64, _record: &str, _emit: &mut dyn FnMut(String, u64)) {
        unreachable!("components is multi-input; run it through the iterative driver");
    }

    fn map_rel(&self, rel: usize, doc: u64, record: &str, emit: &mut dyn FnMut(String, u64)) {
        if let Some(p) = self.parse_rel(rel, doc, record) {
            self.map_parsed(rel, &p, emit);
        }
    }

    /// Min: idempotent, commutative, associative — fold order, duplicate
    /// edges, and shuffle arrival order are all invisible.
    fn combine(acc: &mut u64, v: u64) {
        *acc = (*acc).min(v);
    }

    fn finalize(&self, entries: Vec<(String, u64)>) -> HashMap<String, u64> {
        entries.into_iter().collect()
    }
}

impl CacheableWorkload for ComponentsStep {
    type Parsed = CcParsed;

    fn parse_rel(&self, rel: usize, _doc: u64, record: &str) -> Option<CcParsed> {
        match rel {
            CC_EDGES => {
                let mut toks = record.split_whitespace();
                let src = toks.next()?;
                let dsts: Vec<String> = toks.map(str::to_string).collect();
                if dsts.is_empty() {
                    // A fragment with no neighbors propagates nothing.
                    return None;
                }
                Some(CcParsed::Edges { src: src.to_string(), dsts })
            }
            CC_STATE => {
                let mut toks = record.split_whitespace();
                let node = toks.next()?;
                let label = toks.next()?.parse().ok()?;
                Some(CcParsed::Node(node.to_string(), label))
            }
            other => panic!("components got relation index {other}"),
        }
    }

    fn map_parsed(&self, _rel: usize, parsed: &CcParsed, emit: &mut dyn FnMut(String, u64)) {
        match parsed {
            CcParsed::Edges { src, dsts } => {
                let src_label = self.labels.get(src).copied();
                for dst in dsts {
                    // Undirected edge: each endpoint offers its label to
                    // the other.
                    if let Some(l) = src_label {
                        emit(dst.clone(), l);
                    }
                    if let Some(&l) = self.labels.get(dst) {
                        emit(src.clone(), l);
                    }
                }
            }
            CcParsed::Node(n, l) => emit(n.clone(), *l),
        }
    }
}

/// The iterative connected-components driver workload. Run it with
/// [`run_iterative`](crate::mapreduce::run_iterative) over a single edge
/// relation.
#[derive(Clone, Copy, Debug, Default)]
pub struct Components;

impl Components {
    pub fn new() -> Self {
        Self
    }

    /// `node label` → components.
    fn parse_state_line(line: &str) -> Option<(&str, u64)> {
        let mut t = line.split_whitespace();
        let node = t.next()?;
        let label = t.next()?.parse().ok()?;
        Some((node, label))
    }

    /// Decode a state relation into `(node, label)` pairs — for display
    /// and assertions.
    pub fn labels_from_state(state: &[String]) -> Vec<(String, u64)> {
        state
            .iter()
            .filter_map(|l| Self::parse_state_line(l))
            .map(|(n, lab)| (n.to_string(), lab))
            .collect()
    }

    /// Component sizes at a fixed point, largest first (ties by label).
    pub fn component_sizes(state: &[String]) -> Vec<(u64, usize)> {
        let mut sizes: HashMap<u64, usize> = HashMap::new();
        for (_, label) in Self::labels_from_state(state) {
            *sizes.entry(label).or_insert(0) += 1;
        }
        let mut out: Vec<(u64, usize)> = sizes.into_iter().collect();
        out.sort_by(|a, b| b.1.cmp(&a.1).then(a.0.cmp(&b.0)));
        out
    }
}

impl IterativeWorkload for Components {
    type Step = ComponentsStep;

    fn name(&self) -> &'static str {
        "components"
    }

    /// Every node mentioned anywhere in the edge relation gets a distinct
    /// label — its index in sorted node order.
    fn init_state(&self, inputs: &JobInputs) -> Vec<String> {
        let mut nodes: BTreeSet<&str> = BTreeSet::new();
        for line in inputs.relations[CC_EDGES].lines.iter() {
            for tok in line.split_whitespace() {
                nodes.insert(tok);
            }
        }
        nodes
            .iter()
            .enumerate()
            .map(|(i, node)| format!("{node} {i}"))
            .collect()
    }

    fn step(&self, state: &[String]) -> Arc<ComponentsStep> {
        let labels = state
            .iter()
            .filter_map(|l| Self::parse_state_line(l).map(|(n, lab)| (n.to_string(), lab)))
            .collect::<HashMap<_, _>>();
        Arc::new(ComponentsStep { labels })
    }

    /// `new = min(old, inflow)` per node, in the state's (sorted) order;
    /// delta counts changed labels, so 0 is exact convergence.
    fn advance(&self, output: HashMap<String, u64>, state: &[String]) -> (Vec<String>, f64) {
        let mut changed = 0u64;
        let mut next = Vec::with_capacity(state.len());
        for line in state {
            let Some((node, old)) = Self::parse_state_line(line) else { continue };
            let new = output.get(node).copied().unwrap_or(old).min(old);
            if new != old {
                changed += 1;
            }
            next.push(format!("{node} {new}"));
        }
        (next, changed as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::Corpus;
    use crate::mapreduce::{run_iterative_serial, IterativeSpec};

    fn inputs(edges: &str) -> JobInputs {
        JobInputs::new().relation("edges", &Corpus::from_text(edges))
    }

    fn converged_labels(edges: &str, max_iters: usize) -> Vec<(String, u64)> {
        let out = run_iterative_serial(&IterativeSpec::new(max_iters), &Components::new(), &inputs(edges));
        assert!(out.converged, "did not converge: deltas {:?}", out.deltas);
        Components::labels_from_state(&out.state)
    }

    #[test]
    fn two_components_get_two_labels() {
        let labels: HashMap<String, u64> =
            converged_labels("a b\nb c\nx y\n", 10).into_iter().collect();
        assert_eq!(labels.len(), 5);
        assert_eq!(labels["a"], labels["b"]);
        assert_eq!(labels["b"], labels["c"]);
        assert_eq!(labels["x"], labels["y"]);
        assert_ne!(labels["a"], labels["x"]);
    }

    #[test]
    fn chain_converges_to_min_label() {
        // Path a-b-c-d-e: everyone ends with a's label (0, the sorted
        // minimum); a 4-hop diameter needs multiple propagation rounds.
        let labels: HashMap<String, u64> =
            converged_labels("a b\nb c\nc d\nd e\n", 10).into_iter().collect();
        assert!(labels.values().all(|&l| l == 0), "{labels:?}");
    }

    #[test]
    fn split_adjacency_matches_joined() {
        let a = converged_labels("a b\na c\n", 10);
        let b = converged_labels("a b c\n", 10);
        assert_eq!(a, b);
    }

    #[test]
    fn component_sizes_are_sorted() {
        let out = run_iterative_serial(
            &IterativeSpec::new(10),
            &Components::new(),
            &inputs("a b\nb c\nx y\n"),
        );
        let sizes = Components::component_sizes(&out.state);
        assert_eq!(sizes.iter().map(|&(_, n)| n).collect::<Vec<_>>(), vec![3, 2]);
    }

    #[test]
    fn empty_graph_has_empty_state() {
        let out =
            run_iterative_serial(&IterativeSpec::new(3), &Components::new(), &inputs(""));
        assert!(out.state.is_empty());
        assert!(out.converged, "an empty graph is trivially at its fixed point");
    }

    #[test]
    fn serial_oracle_is_deterministic() {
        let it = IterativeSpec::new(6);
        let i = inputs("a b c\nb d\nq r\nr s\n");
        let x = run_iterative_serial(&it, &Components::new(), &i);
        let y = run_iterative_serial(&it, &Components::new(), &i);
        assert_eq!(x.state, y.state);
        assert_eq!(x.deltas, y.deltas);
    }
}
