//! Equi-join of two relations, co-grouped by key — the first true
//! multi-input workload.
//!
//! Input shape: each line of either relation is `key payload...` — the
//! first space-separated token is the join key, the rest of the line is
//! the payload (possibly empty). [`Join`]'s [`Workload::map_rel`] tags
//! every emission with the side it came from, the engines co-locate both sides of a key
//! through one shuffle (Blaze: the shared [`crate::dist::DistHashMap`];
//! Spark: union-then-`reduceByKey`), and `finalize_local` filters to
//! inner-join semantics — a key survives only if both sides are
//! non-empty. That filter is a valid *filtering partial reduce*: after the
//! exchange each shard holds **all** values of its keys, so the per-key
//! decision is globally correct.

use std::collections::HashMap;

use crate::mapreduce::Workload;
use crate::storage::HeapSize;
use crate::util::ser::{Decode, DecodeError, Encode, Reader};

/// Relation index of the left side in the job's [`crate::mapreduce::JobInputs`].
pub const LEFT: usize = 0;
/// Relation index of the right side.
pub const RIGHT: usize = 1;

/// Partial co-group for one key: the payloads seen on each side so far.
/// This is the shuffle value type, so it carries its own wire format and
/// JVM heap-cost model (the worked example for workload authors who need
/// a value type the framework doesn't already cover).
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct JoinSides {
    pub left: Vec<String>,
    pub right: Vec<String>,
}

impl JoinSides {
    fn one(rel: usize, payload: &str) -> Self {
        let mut sides = Self::default();
        match rel {
            LEFT => sides.left.push(payload.to_string()),
            RIGHT => sides.right.push(payload.to_string()),
            other => panic!("join got relation index {other}, expected {LEFT} or {RIGHT}"),
        }
        sides
    }

    /// Number of joined output pairs this key contributes (|left|·|right|).
    pub fn pairs(&self) -> u64 {
        self.left.len() as u64 * self.right.len() as u64
    }
}

impl Encode for JoinSides {
    fn encode(&self, out: &mut Vec<u8>) {
        self.left.encode(out);
        self.right.encode(out);
    }
}

impl Decode for JoinSides {
    fn decode(r: &mut Reader<'_>) -> Result<Self, DecodeError> {
        Ok(Self { left: Vec::decode(r)?, right: Vec::decode(r)? })
    }
}

impl HeapSize for JoinSides {
    fn heap_bytes(&self) -> usize {
        self.left.heap_bytes() + self.right.heap_bytes() + 16 // object header
    }
}

/// Inner equi-join of two relations, co-grouped by key.
///
/// Output: key → ([`JoinSides`] with both sides sorted), for every key
/// present in *both* relations. Run it with
/// `JobSpec::run_inputs(&w, &JobInputs::new().relation("left", ..).relation("right", ..))`.
#[derive(Clone, Copy, Debug, Default)]
pub struct Join;

impl Join {
    pub fn new() -> Self {
        Join
    }

    /// `key payload` split of one record; `None` for blank lines.
    fn split_record(record: &str) -> Option<(&str, &str)> {
        let rec = record.trim();
        if rec.is_empty() {
            return None;
        }
        match rec.split_once(' ') {
            Some((key, rest)) => Some((key, rest.trim())),
            None => Some((rec, "")),
        }
    }
}

impl Workload for Join {
    type Key = String;
    type Value = JoinSides;
    type Output = HashMap<String, JoinSides>;

    fn name(&self) -> &'static str {
        "join"
    }

    fn num_relations(&self) -> usize {
        2
    }

    /// Multi-input stub: engines and oracles route through `map_rel`, and
    /// the job layer rejects single-relation inputs before any mapping.
    fn map(&self, _doc: u64, _record: &str, _emit: &mut dyn FnMut(String, JoinSides)) {
        unreachable!("join is multi-input; use map_rel (run it via run_inputs/run_serial_inputs)");
    }

    fn map_rel(
        &self,
        rel: usize,
        _doc: u64,
        record: &str,
        emit: &mut dyn FnMut(String, JoinSides),
    ) {
        if let Some((key, payload)) = Self::split_record(record) {
            emit(key.to_string(), JoinSides::one(rel, payload));
        }
    }

    fn combine(acc: &mut JoinSides, mut v: JoinSides) {
        acc.left.append(&mut v.left);
        acc.right.append(&mut v.right);
    }

    /// Inner-join filter: post-shuffle each shard holds every value of its
    /// keys, so dropping keys with an empty side here is exact.
    fn finalize_local(
        &self,
        shard: Vec<(String, JoinSides)>,
    ) -> Vec<(String, JoinSides)> {
        shard
            .into_iter()
            .filter(|(_, s)| !s.left.is_empty() && !s.right.is_empty())
            .collect()
    }

    /// Payloads arrive in shuffle order; sorting both sides makes the
    /// co-groups deterministic across engines and cluster shapes.
    fn finalize(&self, entries: Vec<(String, JoinSides)>) -> HashMap<String, JoinSides> {
        entries
            .into_iter()
            .map(|(k, mut s)| {
                s.left.sort_unstable();
                s.right.sort_unstable();
                (k, s)
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::Corpus;
    use crate::mapreduce::{run_serial_inputs, JobInputs};

    fn inputs(left: &str, right: &str) -> JobInputs {
        JobInputs::new()
            .relation("left", &Corpus::from_text(left))
            .relation("right", &Corpus::from_text(right))
    }

    #[test]
    fn inner_join_co_groups() {
        let out = run_serial_inputs(
            &Join::new(),
            &inputs("a 1\nb 2\na 3\nc 9\n", "a x\nb y\nb z\nd q\n"),
        );
        assert_eq!(out.len(), 2, "only keys on both sides survive: {out:?}");
        assert_eq!(
            out["a"],
            JoinSides { left: vec!["1".into(), "3".into()], right: vec!["x".into()] }
        );
        assert_eq!(
            out["b"],
            JoinSides { left: vec!["2".into()], right: vec!["y".into(), "z".into()] }
        );
        assert_eq!(out["a"].pairs(), 2);
    }

    #[test]
    fn empty_side_yields_empty_join() {
        let out = run_serial_inputs(&Join::new(), &inputs("a 1\nb 2\n", ""));
        assert!(out.is_empty());
    }

    #[test]
    fn keyless_payload_and_blank_lines() {
        // Single-token lines join with empty payloads; blank lines vanish.
        let out = run_serial_inputs(&Join::new(), &inputs("k\n\n", "k v\n   \n"));
        assert_eq!(out["k"], JoinSides { left: vec!["".into()], right: vec!["v".into()] });
    }

    #[test]
    fn sides_roundtrip_wire_format() {
        let s = JoinSides { left: vec!["a b".into(), "".into()], right: vec!["c".into()] };
        let bytes = s.to_bytes();
        assert_eq!(JoinSides::from_bytes(&bytes).unwrap(), s);
        assert!(s.heap_bytes() > 0);
    }
}
