//! PageRank — the canonical iterative MapReduce workload: rank mass
//! exchanged over an edge relation until the L1 change between rounds
//! drops below tolerance.
//!
//! Input shape: each line of the (static) edge relation is an adjacency
//! fragment `src dst1 dst2 ...` — the first whitespace token is a node,
//! the rest are its out-neighbors. A node's adjacency may be split across
//! any number of lines (out-degrees are totaled at init). The fed-back
//! state relation holds one line per node: `node rank_units out_degree`.
//!
//! # Fixed-point arithmetic
//!
//! Ranks live on an integer grid: [`PR_SCALE`] units ≡ rank 1.0. Every
//! per-round operation — the per-edge share `rank / out_degree`, the
//! inflow sum, the damping `base + inflow·d/100` — is integer arithmetic,
//! so results are independent of combine order and **bit-identical**
//! across the serial oracle and both engines, on any cluster shape. (The
//! float formulation would differ in the last ulps depending on shuffle
//! arrival order.) Dangling nodes (no out-edges) simply drop their mass,
//! the usual simplification; total mass then decays slightly below 1.0
//! but the damped iteration still contracts to its fixed point.
//!
//! # Round structure
//!
//! * map over an edge fragment: look the source's `(rank, out_degree)` up
//!   in the **broadcast** previous state and emit
//!   `(dst, rank / out_degree)` per listed neighbor;
//! * map over a state line: emit `(node, 0)` so every node appears in the
//!   reduced output even with no inbound mass;
//! * combine: integer sum — the inflow;
//! * `PageRank::advance`: `new = teleport + d · inflow / 100`, L1 delta
//!   against the previous ranks, state re-rendered in sorted node order.
//!
//! Edge parsing is the cacheable half ([`CacheableWorkload`]): the edge
//! relation never changes across rounds, so with a warm
//! [`crate::cache::PartitionCache`] every round after the first skips
//! tokenization and goes straight to the rank lookups.

use std::collections::{BTreeMap, HashMap};
use std::sync::Arc;

use crate::mapreduce::{CacheableWorkload, IterativeWorkload, JobInputs, Workload};
use crate::storage::HeapSize;
use crate::util::ser::{Decode, DecodeError, Encode, Reader};

/// Fixed-point scale: this many integer units ≡ rank 1.0.
pub const PR_SCALE: u64 = 1 << 32;

/// Relation index of the static edge relation.
pub const PR_EDGES: usize = 0;
/// Relation index of the fed-back state relation.
pub const PR_STATE: usize = 1;

/// Parsed form of one record — what the partition cache stores per split.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum PrParsed {
    /// One adjacency fragment of the edge relation.
    Edges { src: String, dsts: Vec<String> },
    /// One node of the state relation.
    Node(String),
}

impl HeapSize for PrParsed {
    fn heap_bytes(&self) -> usize {
        match self {
            PrParsed::Edges { src, dsts } => src.heap_bytes() + dsts.heap_bytes() + 16,
            PrParsed::Node(n) => n.heap_bytes() + 16,
        }
    }
}

// Wire form (tag byte + fields) so cached parse blocks can demote to the
// disk tier under memory pressure.
impl Encode for PrParsed {
    fn encode(&self, out: &mut Vec<u8>) {
        match self {
            PrParsed::Edges { src, dsts } => {
                out.push(0);
                src.encode(out);
                dsts.encode(out);
            }
            PrParsed::Node(n) => {
                out.push(1);
                n.encode(out);
            }
        }
    }
}

impl Decode for PrParsed {
    fn decode(r: &mut Reader<'_>) -> Result<Self, DecodeError> {
        match u8::decode(r)? {
            0 => Ok(PrParsed::Edges { src: String::decode(r)?, dsts: Vec::decode(r)? }),
            1 => Ok(PrParsed::Node(String::decode(r)?)),
            t => Err(DecodeError::BadTag(t)),
        }
    }
}

/// One round of PageRank: inflow accumulation with the previous ranks
/// broadcast into the workload (built fresh each round by
/// `PageRank::step`).
pub struct PageRankStep {
    /// node → (rank units, out-degree) of the previous round.
    ranks: HashMap<String, (u64, u64)>,
}

impl Workload for PageRankStep {
    type Key = String;
    type Value = u64;
    type Output = HashMap<String, u64>;

    fn name(&self) -> &'static str {
        "pagerank"
    }

    fn num_relations(&self) -> usize {
        2
    }

    /// Multi-input stub: engines and oracles route through `map_rel`.
    fn map(&self, _doc: u64, _record: &str, _emit: &mut dyn FnMut(String, u64)) {
        unreachable!("pagerank is multi-input; run it through the iterative driver");
    }

    fn map_rel(&self, rel: usize, doc: u64, record: &str, emit: &mut dyn FnMut(String, u64)) {
        if let Some(p) = self.parse_rel(rel, doc, record) {
            self.map_parsed(rel, &p, emit);
        }
    }

    fn combine(acc: &mut u64, v: u64) {
        *acc += v;
    }

    fn finalize(&self, entries: Vec<(String, u64)>) -> HashMap<String, u64> {
        entries.into_iter().collect()
    }
}

impl CacheableWorkload for PageRankStep {
    type Parsed = PrParsed;

    fn parse_rel(&self, rel: usize, _doc: u64, record: &str) -> Option<PrParsed> {
        match rel {
            PR_EDGES => {
                let mut toks = record.split_whitespace();
                let src = toks.next()?;
                let dsts: Vec<String> = toks.map(str::to_string).collect();
                if dsts.is_empty() {
                    // A fragment with no out-neighbors emits nothing.
                    return None;
                }
                Some(PrParsed::Edges { src: src.to_string(), dsts })
            }
            PR_STATE => {
                record.split_whitespace().next().map(|n| PrParsed::Node(n.to_string()))
            }
            other => panic!("pagerank got relation index {other}"),
        }
    }

    fn map_parsed(&self, _rel: usize, parsed: &PrParsed, emit: &mut dyn FnMut(String, u64)) {
        match parsed {
            PrParsed::Edges { src, dsts } => {
                let Some(&(rank, deg)) = self.ranks.get(src) else {
                    return; // source unknown to the state: no mass to send
                };
                if deg == 0 {
                    return;
                }
                // Integer share per out-edge occurrence: order-free.
                let share = rank / deg;
                for dst in dsts {
                    emit(dst.clone(), share);
                }
            }
            PrParsed::Node(n) => emit(n.clone(), 0),
        }
    }
}

/// The iterative PageRank driver workload: owns the damping factor and the
/// state round-tripping. Run it with
/// [`run_iterative`](crate::mapreduce::run_iterative) over a single edge
/// relation.
#[derive(Clone, Copy, Debug)]
pub struct PageRank {
    /// Damping factor in percent (the classic 0.85 → 85).
    pub damping_pct: u64,
}

impl Default for PageRank {
    fn default() -> Self {
        Self { damping_pct: 85 }
    }
}

impl PageRank {
    pub fn new() -> Self {
        Self::default()
    }

    /// Per-node teleport mass for an `n`-node graph.
    fn base_units(&self, n: u64) -> u64 {
        PR_SCALE / 100 * (100 - self.damping_pct) / n.max(1)
    }

    /// `node rank_units out_degree` → components.
    fn parse_state_line(line: &str) -> Option<(&str, u64, u64)> {
        let mut t = line.split_whitespace();
        let node = t.next()?;
        let rank = t.next()?.parse().ok()?;
        let deg = t.next()?.parse().ok()?;
        Some((node, rank, deg))
    }

    /// Decode a state relation into `(node, rank in [0,1])` pairs — for
    /// display and assertions.
    pub fn ranks_from_state(state: &[String]) -> Vec<(String, f64)> {
        state
            .iter()
            .filter_map(|l| Self::parse_state_line(l))
            .map(|(n, r, _)| (n.to_string(), r as f64 / PR_SCALE as f64))
            .collect()
    }
}

impl IterativeWorkload for PageRank {
    type Step = PageRankStep;

    fn name(&self) -> &'static str {
        "pagerank"
    }

    /// Node set and out-degrees from one scan of the edge relation;
    /// everyone starts at rank `1/n` (on the integer grid), sorted by
    /// node name.
    fn init_state(&self, inputs: &JobInputs) -> Vec<String> {
        let mut degs: BTreeMap<&str, u64> = BTreeMap::new();
        for line in inputs.relations[PR_EDGES].lines.iter() {
            let mut toks = line.split_whitespace();
            let Some(src) = toks.next() else { continue };
            let mut fanout = 0u64;
            for dst in toks {
                degs.entry(dst).or_insert(0);
                fanout += 1;
            }
            *degs.entry(src).or_insert(0) += fanout;
        }
        let n = degs.len() as u64;
        if n == 0 {
            return Vec::new();
        }
        let init = PR_SCALE / n;
        degs.iter().map(|(node, deg)| format!("{node} {init} {deg}")).collect()
    }

    fn step(&self, state: &[String]) -> Arc<PageRankStep> {
        let ranks = state
            .iter()
            .filter_map(|l| {
                Self::parse_state_line(l).map(|(n, r, d)| (n.to_string(), (r, d)))
            })
            .collect::<HashMap<_, _>>();
        Arc::new(PageRankStep { ranks })
    }

    /// `new = teleport + d·inflow/100` per node, in the state's (sorted)
    /// order; delta is the L1 rank change normalized to rank mass 1.0.
    fn advance(&self, output: HashMap<String, u64>, state: &[String]) -> (Vec<String>, f64) {
        let base = self.base_units(state.len() as u64);
        let mut delta_units = 0u64;
        let mut next = Vec::with_capacity(state.len());
        for line in state {
            let Some((node, rank, deg)) = Self::parse_state_line(line) else { continue };
            let inflow = output.get(node).copied().unwrap_or(0);
            let new = base + inflow * self.damping_pct / 100;
            delta_units += new.abs_diff(rank);
            next.push(format!("{node} {new} {deg}"));
        }
        (next, delta_units as f64 / PR_SCALE as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::Corpus;
    use crate::mapreduce::{run_iterative_serial, IterativeSpec};

    fn inputs(edges: &str) -> JobInputs {
        JobInputs::new().relation("edges", &Corpus::from_text(edges))
    }

    /// a → b, b → c, c → a (a 3-cycle): symmetric, so ranks stay equal
    /// (up to integer-grid drift, which contracts by the damping factor
    /// each round).
    #[test]
    fn cycle_keeps_uniform_ranks() {
        let out = run_iterative_serial(
            &IterativeSpec::new(30).tolerance(1e-8),
            &PageRank::new(),
            &inputs("a b\nb c\nc a\n"),
        );
        assert!(out.converged, "symmetric cycle converges: {:?}", out.deltas);
        let ranks = PageRank::ranks_from_state(&out.state);
        assert_eq!(ranks.len(), 3);
        for (_, r) in &ranks {
            assert!((r - 1.0 / 3.0).abs() < 1e-6, "uniform ranks, got {ranks:?}");
        }
    }

    /// Everyone links to `hub`; the hub must out-rank the leaves.
    #[test]
    fn hub_accumulates_rank() {
        let out = run_iterative_serial(
            &IterativeSpec::new(30).tolerance(1e-7),
            &PageRank::new(),
            &inputs("a hub\nb hub\nc hub\nhub a\n"),
        );
        let ranks: HashMap<String, f64> =
            PageRank::ranks_from_state(&out.state).into_iter().collect();
        assert!(ranks["hub"] > ranks["b"] * 2.0, "{ranks:?}");
        assert!(ranks["a"] > ranks["b"], "hub links back to a: {ranks:?}");
    }

    #[test]
    fn serial_oracle_is_deterministic() {
        let it = IterativeSpec::new(8).tolerance(0.0);
        let i = inputs("a b c\nb c\nc a\nd a b c d\n");
        let x = run_iterative_serial(&it, &PageRank::new(), &i);
        let y = run_iterative_serial(&it, &PageRank::new(), &i);
        assert_eq!(x.state, y.state);
        assert_eq!(x.deltas, y.deltas);
    }

    #[test]
    fn split_adjacency_totals_out_degree() {
        // `a`'s adjacency split over two lines: shares must use deg 2.
        let one = run_iterative_serial(
            &IterativeSpec::new(1),
            &PageRank::new(),
            &inputs("a b\na c\n"),
        );
        let split: HashMap<String, f64> =
            PageRank::ranks_from_state(&one.state).into_iter().collect();
        let joined = run_iterative_serial(
            &IterativeSpec::new(1),
            &PageRank::new(),
            &inputs("a b c\n"),
        );
        let whole: HashMap<String, f64> =
            PageRank::ranks_from_state(&joined.state).into_iter().collect();
        assert_eq!(split, whole);
    }

    #[test]
    fn empty_graph_has_empty_state() {
        let out = run_iterative_serial(&IterativeSpec::new(3), &PageRank::new(), &inputs(""));
        assert!(out.state.is_empty());
    }

    #[test]
    fn state_lines_roundtrip() {
        let w = PageRank::new();
        let state = w.init_state(&inputs("x y\ny x\n"));
        assert_eq!(state.len(), 2);
        for line in &state {
            let (n, r, d) = PageRank::parse_state_line(line).unwrap();
            assert!(!n.is_empty());
            assert_eq!(r, PR_SCALE / 2);
            assert_eq!(d, 1);
        }
    }
}
