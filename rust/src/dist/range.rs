//! `DistRange` — the paper's distributed index space.
//!
//! A `DistRange` describes the iteration space `start..end` (with an
//! optional non-unit step). [`DistRange::node_block`] splits it into one
//! contiguous block per node — the MPI decomposition — and
//! [`DistRange::mapreduce`] runs the paper's whole pipeline on one node:
//! OpenMP-style threads map this node's block, emissions combine into a
//! [`DistHashMap`], and one all-to-all shuffle re-shards by key owner.

use crate::cluster::Comm;
use crate::concurrent::{MapKey, MapValue};
use crate::util::pool::{self, Schedule};
use crate::util::ser::{DataKey, Decode, Encode};

use super::DistHashMap;

/// A `[start, end)` index space with a step, partitionable across nodes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct DistRange {
    start: i64,
    end: i64,
    step: i64,
}

impl DistRange {
    /// Unit-step range over `[start, end)`.
    pub fn new(start: i64, end: i64) -> DistRange {
        DistRange::with_step(start, end, 1)
    }

    /// Range with an explicit step. A positive step iterates `start`,
    /// `start+step`, ... while `< end`; a negative step iterates downward
    /// while `> end`.
    pub fn with_step(start: i64, end: i64, step: i64) -> DistRange {
        assert!(step != 0, "DistRange step must be non-zero");
        DistRange { start, end, step }
    }

    /// Number of iterations in the range.
    pub fn len(&self) -> usize {
        if self.step > 0 {
            if self.end <= self.start {
                0
            } else {
                ((self.end - self.start + self.step - 1) / self.step) as usize
            }
        } else {
            let step = -self.step;
            if self.start <= self.end {
                0
            } else {
                ((self.start - self.end + step - 1) / step) as usize
            }
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// The `i`-th iteration value.
    pub fn at(&self, i: usize) -> i64 {
        self.start + (i as i64) * self.step
    }

    /// This node's contiguous block of iteration indices, as `[lo, hi)`
    /// over `0..len()`. Blocks partition the space exactly: block `r`
    /// starts where block `r-1` ends, the remainder is spread over the
    /// first `len % nnodes` nodes.
    pub fn node_block(&self, rank: usize, nnodes: usize) -> (usize, usize) {
        assert!(nnodes > 0 && rank < nnodes);
        let n = self.len();
        let base = n / nnodes;
        let rem = n % nnodes;
        let lo = rank * base + rank.min(rem);
        let hi = lo + base + usize::from(rank < rem);
        (lo, hi)
    }

    /// The paper's high-level operation, executed on one node of the
    /// cluster: map this node's block with `nthreads` workers, emitting
    /// `(K, V)` pairs into `target` (combined continuously per
    /// [`super::CombineMode`]), then shuffle so every key lives on its
    /// owner node. Call from every rank; collect results with
    /// [`DistHashMap::to_vec_local`].
    pub fn mapreduce<K, V, R, F>(
        &self,
        comm: &Comm,
        nthreads: usize,
        target: &DistHashMap<K, V>,
        reduce: R,
        mapper: F,
    ) where
        K: MapKey + DataKey + Encode + Decode,
        V: MapValue + Encode + Decode,
        R: Fn(&mut V, V) + Sync,
        F: Fn(i64, &mut dyn FnMut(K, V)) + Sync,
    {
        let (lo, hi) = self.node_block(comm.rank, comm.nnodes());
        pool::parallel_for_range(nthreads, lo, hi, Schedule::Dynamic { chunk: 64 }, |ctx, i| {
            mapper(self.at(i), &mut |k, v| target.upsert(ctx.worker, k, v, &reduce));
        });
        target.shuffle(comm, reduce, true);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unit_range_basics() {
        let r = DistRange::new(0, 10);
        assert_eq!(r.len(), 10);
        assert_eq!(r.at(0), 0);
        assert_eq!(r.at(9), 9);
    }

    #[test]
    fn empty_ranges() {
        assert_eq!(DistRange::new(5, 5).len(), 0);
        assert_eq!(DistRange::new(7, 3).len(), 0);
        assert!(DistRange::new(7, 3).is_empty());
    }

    #[test]
    fn stepped_ranges() {
        let r = DistRange::with_step(0, 10, 3); // 0 3 6 9
        assert_eq!(r.len(), 4);
        assert_eq!(r.at(3), 9);
        let r = DistRange::with_step(10, 0, -3); // 10 7 4 1
        assert_eq!(r.len(), 4);
        assert_eq!(r.at(3), 1);
        let r = DistRange::with_step(-5, 5, 2); // -5 -3 -1 1 3
        assert_eq!(r.len(), 5);
        assert_eq!(r.at(4), 3);
    }

    #[test]
    fn node_blocks_partition_exactly() {
        for n in [0usize, 1, 7, 100, 101] {
            let r = DistRange::new(0, n as i64);
            for nnodes in [1usize, 2, 3, 8] {
                let mut prev = 0usize;
                for rank in 0..nnodes {
                    let (lo, hi) = r.node_block(rank, nnodes);
                    assert_eq!(lo, prev, "n={n} nnodes={nnodes} rank={rank}");
                    assert!(hi >= lo);
                    prev = hi;
                }
                assert_eq!(prev, r.len(), "n={n} nnodes={nnodes}");
            }
        }
    }
}
