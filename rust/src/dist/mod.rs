//! The paper's distributed data structures: [`DistRange`] (an index space
//! partitioned across nodes, mapped by OpenMP-style threads) and
//! [`DistHashMap`] (a key-sharded hash map with continuous map-side
//! combining and a one-shot all-to-all shuffle).
//!
//! Together they are the MPI/OpenMP MapReduce substrate:
//!
//! ```text
//! DistRange::mapreduce:
//!   node block of [start, end)  --map-->  (K, V) emissions
//!       --continuous combine-->  DistHashMap (local, ConcurrentHashMap)
//!       --all-to-all shuffle-->  key's owner node (bytes measured on wire)
//! ```
//!
//! [`CombineMode`] toggles the paper's third claim (A3): `Eager` combines
//! emissions continuously in the local map before anything is shipped;
//! `None` buffers every raw `(K, V)` pair and ships them all, so the
//! shuffle-byte delta between the two modes is exactly the local-reduce
//! saving the paper describes.

pub mod map;
pub mod range;
pub mod reducer;

pub use map::DistHashMap;
pub use range::DistRange;

/// When map-side combining happens (ablation A3).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CombineMode {
    /// Combine continuously during the map phase (the paper's design).
    Eager,
    /// Ship every raw emission; reduce only after the shuffle.
    None,
}

impl CombineMode {
    pub fn parse(s: &str) -> Option<CombineMode> {
        match s {
            "eager" => Some(CombineMode::Eager),
            "none" => Some(CombineMode::None),
            _ => None,
        }
    }

    pub fn label(self) -> &'static str {
        match self {
            CombineMode::Eager => "eager",
            CombineMode::None => "none",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn combine_mode_parse() {
        assert_eq!(CombineMode::parse("eager"), Some(CombineMode::Eager));
        assert_eq!(CombineMode::parse("none"), Some(CombineMode::None));
        assert_eq!(CombineMode::parse("lazy"), None);
    }
}
