//! `DistHashMap` — the paper's distributed hash map.
//!
//! Every node holds a local [`ConcurrentHashMap`]; keys are owner-sharded
//! by hash (`owner = bucket_of(hash(key), nnodes)`, the same high-bits
//! bucketing the single-node segments use). During the map phase each node
//! upserts whatever its mappers emit — for keys it owns *and* keys it
//! doesn't — and the local map combines continuously ([`CombineMode::Eager`],
//! the paper's "local reduce during the map phase"). One
//! [`DistHashMap::shuffle`] then re-shards: each node serializes the
//! entries it doesn't own, all-to-all exchanges them over the simulated
//! fabric (bytes measured on the wire), and merges what it receives, after
//! which every key lives exactly once, on its owner.
//!
//! With [`CombineMode::None`] the map phase instead buffers every raw
//! `(K, V)` emission per thread and the shuffle ships them all — the
//! ablation that quantifies the paper's local-reduce claim.

use std::sync::{Arc, Mutex};

use crate::cluster::Comm;
use crate::concurrent::{default_segments, CachePolicy, ConcurrentHashMap, MapKey, MapValue};
use crate::hash::{bucket_of, HashKind};
use crate::storage::{fresh_spill_namespace, BlockStore, DiskTier, ExternalMerger, HeapSize};
use crate::util::ser::{
    decode_varint, encode_pairs, DataKey, Decode, DictReader, DictStats, Encode, Reader,
};

use super::CombineMode;

pub struct DistHashMap<K: MapKey, V: MapValue> {
    rank: usize,
    nnodes: usize,
    nthreads: usize,
    hash: HashKind,
    combine: CombineMode,
    /// Local table: pending (pre-shuffle) entries under `Eager`, and the
    /// owned shard after a shuffle in either mode.
    local: ConcurrentHashMap<K, V>,
    /// Per-thread raw emission buffers (`CombineMode::None` only).
    raw: Vec<Mutex<Vec<(K, V)>>>,
}

impl<K: MapKey, V: MapValue> DistHashMap<K, V> {
    pub fn new(
        rank: usize,
        nnodes: usize,
        nthreads: usize,
        hash: HashKind,
        combine: CombineMode,
    ) -> Self {
        Self::with_policy(rank, nnodes, nthreads, hash, combine, CachePolicy::default())
    }

    pub fn with_policy(
        rank: usize,
        nnodes: usize,
        nthreads: usize,
        hash: HashKind,
        combine: CombineMode,
        policy: CachePolicy,
    ) -> Self {
        assert!(nnodes > 0 && rank < nnodes && nthreads > 0);
        Self {
            rank,
            nnodes,
            nthreads,
            hash,
            combine,
            local: ConcurrentHashMap::with_policy(
                default_segments(nthreads),
                nthreads,
                hash,
                policy,
            ),
            raw: (0..nthreads).map(|_| Mutex::new(Vec::new())).collect(),
        }
    }

    pub fn rank(&self) -> usize {
        self.rank
    }

    pub fn nnodes(&self) -> usize {
        self.nnodes
    }

    pub fn combine_mode(&self) -> CombineMode {
        self.combine
    }

    /// Which rank owns `key` after the shuffle.
    pub fn owner_of(&self, key: &K) -> usize {
        bucket_of(key.hash_with(self.hash), self.nnodes)
    }

    /// Map-phase insert from worker thread `tid`. Under `Eager` the value
    /// combines into the local map immediately; under `None` the raw pair
    /// is buffered for the shuffle.
    #[inline]
    pub fn upsert(&self, tid: usize, key: K, value: V, reduce: impl Fn(&mut V, V)) {
        match self.combine {
            CombineMode::Eager => self.local.upsert(tid, key, value, reduce),
            CombineMode::None => self.raw[tid].lock().unwrap().push((key, value)),
        }
    }

    /// Entries currently owned locally. Complete only after
    /// [`shuffle`](Self::shuffle); the per-node shards are disjoint, so
    /// concatenating every node's `to_vec_local` yields the global result.
    pub fn to_vec_local(&self) -> Vec<(K, V)> {
        self.local.to_vec()
    }

    /// Make this node's emissions readable **without** the exchange — the
    /// zero-shuffle fast path for workloads whose keys never need
    /// co-location (each key emitted at most once globally). Thread caches
    /// are synced into the local table; under [`CombineMode::None`] the raw
    /// per-thread buffers are folded in first. Unlike
    /// [`shuffle`](Self::shuffle), entries stay on the node that produced
    /// them (still globally disjoint under the uniqueness contract) and
    /// nothing touches the fabric.
    pub fn settle_local(&self, reduce: impl Fn(&mut V, V) + Sync) {
        if self.combine == CombineMode::None {
            for cell in &self.raw {
                for (k, v) in cell.lock().unwrap().drain(..) {
                    self.local.upsert(0, k, v, &reduce);
                }
            }
        }
        self.local.sync(self.nthreads, &reduce);
    }

    /// Drain pending entries (thread caches or raw buffers) into
    /// owner-sharded buckets — step 1+2 of either shuffle flavor.
    fn drain_by_owner(&self, reduce: &(impl Fn(&mut V, V) + Sync)) -> Vec<Vec<(K, V)>> {
        let n = self.nnodes;
        let mut pending: Vec<(u64, K, V)> = Vec::new();
        match self.combine {
            CombineMode::Eager => {
                self.local.sync(self.nthreads, reduce);
                for e in self.local.drain_entries() {
                    pending.push((e.hash, e.key, e.value));
                }
            }
            CombineMode::None => {
                for cell in &self.raw {
                    for (k, v) in cell.lock().unwrap().drain(..) {
                        let h = k.hash_with(self.hash);
                        pending.push((h, k, v));
                    }
                }
            }
        }
        let mut by_owner: Vec<Vec<(K, V)>> = (0..n).map(|_| Vec::new()).collect();
        for (h, k, v) in pending {
            by_owner[bucket_of(h, n)].push((k, v));
        }
        by_owner
    }

    /// The all-to-all re-shard: collect every pending entry, ship each to
    /// its owner (self-delivery stays typed and off the wire), merge what
    /// arrives. After this, the map holds exactly this rank's shard.
    ///
    /// Wire payloads carry dictionary-encoded keys when `dict` is on:
    /// each repeated key crosses the fabric once, later occurrences as a
    /// varint back-reference (the [`crate::util::ser::DictWriter`]
    /// format). The receive path decodes into per-payload
    /// [`DictReader`] arenas and upserts through borrowed key handles,
    /// materializing an owned key only on first sight. Returns the
    /// outgoing-payload dictionary stats.
    pub fn shuffle(
        &self,
        comm: &Comm,
        reduce: impl Fn(&mut V, V) + Sync,
        dict: bool,
    ) -> DictStats
    where
        K: DataKey,
        V: Encode + Decode,
    {
        assert_eq!(comm.nnodes(), self.nnodes, "comm/map cluster size mismatch");
        let mut by_owner = self.drain_by_owner(&reduce);

        // 3. Exchange. The local shard bypasses serialization and the
        //    wire — that asymmetry is the measurable local-reduce saving.
        let mine = std::mem::take(&mut by_owner[self.rank]);
        let mut stats = DictStats::default();
        let outgoing: Vec<Vec<u8>> = by_owner
            .iter()
            .enumerate()
            .map(|(dst, shard)| {
                if dst == self.rank {
                    return Vec::new();
                }
                let (bytes, s) = encode_pairs(shard, dict);
                stats = stats.merged(&s);
                bytes
            })
            .collect();
        let incoming = comm.all_to_all(outgoing);

        // 4. Merge own + received into the (now empty) local table.
        for (k, v) in mine {
            self.local.upsert(0, k, v, &reduce);
        }
        for (src, buf) in incoming.into_iter().enumerate() {
            if src == self.rank {
                continue;
            }
            let mut r = Reader::new(&buf);
            let mut ctx = DictReader::new();
            let count = decode_varint(&mut r).expect("dist shuffle decode");
            for _ in 0..count {
                let kr = K::dict_decode(&mut r, &mut ctx).expect("dist shuffle decode");
                let v = V::decode(&mut r).expect("dist shuffle decode");
                let h = K::ref_hash(&kr, &ctx, self.hash);
                self.local.upsert_borrowed(
                    0,
                    h,
                    |k: &K| K::ref_eq_owned(&kr, &ctx, k),
                    || K::ref_materialize(&kr, &ctx),
                    v,
                    &reduce,
                );
            }
            assert!(r.is_empty(), "dist shuffle decode: trailing bytes");
        }
        self.local.sync(self.nthreads, &reduce);
        stats
    }

    /// [`shuffle`](Self::shuffle) with a **bounded-memory merge**: the
    /// exchange is identical (same drain, same owner sharding, same bytes
    /// on the fabric), but the reduce-side merge runs through an
    /// [`ExternalMerger`] — beyond `threshold` estimated in-flight bytes
    /// the partial shard sort-and-spills runs to `disk`, and the merged
    /// shard comes back from a loser-tree external merge. Returns this
    /// node's merged entries (the local table is left drained): for any
    /// associative + commutative `reduce` the result set is identical to
    /// the in-memory shuffle at any threshold down to 0.
    pub fn shuffle_external(
        &self,
        comm: &Comm,
        reduce: impl Fn(&mut V, V) + Sync,
        threshold: u64,
        disk: &Arc<DiskTier>,
        dict: bool,
    ) -> (Vec<(K, V)>, DictStats)
    where
        K: Ord + DataKey + HeapSize,
        V: Encode + Decode + HeapSize,
    {
        assert_eq!(comm.nnodes(), self.nnodes, "comm/map cluster size mismatch");
        let mut by_owner = self.drain_by_owner(&reduce);

        // 3. Exchange — byte-for-byte the same protocol as `shuffle`.
        let mine = std::mem::take(&mut by_owner[self.rank]);
        let mut stats = DictStats::default();
        let outgoing: Vec<Vec<u8>> = by_owner
            .iter()
            .enumerate()
            .map(|(dst, shard)| {
                if dst == self.rank {
                    return Vec::new();
                }
                let (bytes, s) = encode_pairs(shard, dict);
                stats = stats.merged(&s);
                bytes
            })
            .collect();
        let incoming = comm.all_to_all(outgoing);

        // 4. Merge own + received through the budgeted external merger.
        // Received keys stay borrowed handles into the payload's
        // dictionary arena until the merger actually needs an owned key
        // (first sight of the key, or a spill re-materialization).
        let mut merger: ExternalMerger<K, V> = ExternalMerger::new(
            threshold,
            Arc::clone(disk) as Arc<dyn BlockStore>,
            Arc::clone(disk.counters()),
            fresh_spill_namespace(),
        )
        .with_dict_keys(dict);
        for (k, v) in mine {
            merger.insert(k, v, &reduce);
        }
        for (src, buf) in incoming.into_iter().enumerate() {
            if src == self.rank {
                continue;
            }
            let mut r = Reader::new(&buf);
            let mut ctx = DictReader::new();
            let count = decode_varint(&mut r).expect("dist shuffle decode");
            for _ in 0..count {
                let kr = K::dict_decode(&mut r, &mut ctx).expect("dist shuffle decode");
                let v = V::decode(&mut r).expect("dist shuffle decode");
                merger.insert_ref(kr, &ctx, v, &reduce);
            }
            assert!(r.is_empty(), "dist shuffle decode: trailing bytes");
        }
        (merger.finish(&reduce), stats)
    }
}

impl<V: MapValue> DistHashMap<String, V> {
    /// Borrowed-key upsert — the zero-alloc "TCM" hot path: the owned key
    /// is materialized only when the token is seen for the first time.
    #[inline]
    pub fn upsert_str(&self, tid: usize, key: &str, value: V, reduce: impl Fn(&mut V, V)) {
        match self.combine {
            CombineMode::Eager => {
                let hash = self.hash.hash(key.as_bytes());
                self.local.upsert_borrowed(
                    tid,
                    hash,
                    |k: &String| k == key,
                    || key.to_string(),
                    value,
                    reduce,
                );
            }
            CombineMode::None => self.raw[tid].lock().unwrap().push((key.to_string(), value)),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{spawn_cluster, NetModel};
    use crate::dist::reducer;
    use std::collections::HashMap;

    fn count_words(
        nnodes: usize,
        combine: CombineMode,
        words: &[&str],
        dict: bool,
    ) -> HashMap<String, u64> {
        let results = spawn_cluster(nnodes, NetModel::ideal(), |comm| {
            let map: DistHashMap<String, u64> =
                DistHashMap::new(comm.rank, nnodes, 2, HashKind::Fx, combine);
            // Every node inserts the full stream.
            for w in words {
                map.upsert(0, w.to_string(), 1, reducer::sum);
            }
            map.shuffle(comm, reducer::sum, dict);
            map.to_vec_local()
        });
        results.into_iter().flatten().collect()
    }

    #[test]
    fn shuffle_shards_and_totals() {
        let words = ["a", "b", "a", "c", "a", "b"];
        for combine in [CombineMode::Eager, CombineMode::None] {
            for nnodes in [1usize, 2, 3] {
                for dict in [true, false] {
                    let counts = count_words(nnodes, combine, &words, dict);
                    assert_eq!(counts.len(), 3, "{combine:?} nnodes={nnodes} dict={dict}");
                    assert_eq!(counts["a"], 3 * nnodes as u64);
                    assert_eq!(counts["b"], 2 * nnodes as u64);
                    assert_eq!(counts["c"], nnodes as u64);
                }
            }
        }
    }

    #[test]
    fn owned_keys_land_on_owner() {
        let nnodes = 4;
        let results = spawn_cluster(nnodes, NetModel::ideal(), |comm| {
            let map: DistHashMap<String, u64> =
                DistHashMap::new(comm.rank, nnodes, 2, HashKind::Fx, CombineMode::Eager);
            for i in 0..100 {
                map.upsert(0, format!("k{i}"), 1, reducer::sum);
            }
            map.shuffle(comm, reducer::sum, true);
            let owned = map.to_vec_local();
            owned.iter().all(|(k, _)| map.owner_of(k) == comm.rank)
        });
        assert!(results.into_iter().all(|ok| ok));
    }

    #[test]
    fn upsert_str_matches_owned() {
        let words = ["x", "y", "x"];
        let results = spawn_cluster(2, NetModel::ideal(), |comm| {
            let a: DistHashMap<String, u64> =
                DistHashMap::new(comm.rank, 2, 2, HashKind::Fx, CombineMode::Eager);
            let b: DistHashMap<String, u64> =
                DistHashMap::new(comm.rank, 2, 2, HashKind::Fx, CombineMode::Eager);
            for w in words {
                a.upsert(0, w.to_string(), 1, reducer::sum);
                b.upsert_str(0, w, 1, reducer::sum);
            }
            a.shuffle(comm, reducer::sum, true);
            b.shuffle(comm, reducer::sum, false);
            let mut av = a.to_vec_local();
            let mut bv = b.to_vec_local();
            av.sort();
            bv.sort();
            (av, bv)
        });
        for (av, bv) in results {
            assert_eq!(av, bv);
        }
    }

    #[test]
    fn shuffle_external_matches_in_memory_shuffle() {
        use crate::storage::DiskTier;
        let words = ["a", "b", "a", "c", "a", "b"];
        for combine in [CombineMode::Eager, CombineMode::None] {
            // Thresholds bracketing the spectrum: spill-everything and
            // never-spill must both match the plain shuffle.
            for threshold in [0u64, u64::MAX] {
                let results = spawn_cluster(2, NetModel::ideal(), |comm| {
                    let map: DistHashMap<String, u64> =
                        DistHashMap::new(comm.rank, 2, 2, HashKind::Fx, combine);
                    for w in words {
                        map.upsert(0, w.to_string(), 1, reducer::sum);
                    }
                    let disk = Arc::new(DiskTier::new(None));
                    let (merged, _) =
                        map.shuffle_external(comm, reducer::sum, threshold, &disk, true);
                    let spilled = disk.counters().snapshot().spilled_bytes;
                    (merged, spilled)
                });
                let mut spilled_total = 0;
                let merged: HashMap<String, u64> = results
                    .into_iter()
                    .flat_map(|(entries, spilled)| {
                        spilled_total += spilled;
                        entries
                    })
                    .collect();
                assert_eq!(merged.len(), 3, "{combine:?} threshold={threshold}");
                assert_eq!(merged["a"], 6);
                assert_eq!(merged["b"], 4);
                assert_eq!(merged["c"], 2);
                if threshold == 0 {
                    assert!(spilled_total > 0, "threshold 0 must spill ({combine:?})");
                } else {
                    assert_eq!(spilled_total, 0, "unbounded never spills ({combine:?})");
                }
            }
        }
    }

    #[test]
    fn dict_wire_stats_count_repeats() {
        // Two nodes, every key emitted 3x under CombineMode::None, so the
        // wire shard for the remote owner carries repeated keys — the
        // dictionary must register each unique key once and back-reference
        // the rest, and the encoded key bytes must shrink.
        let results = spawn_cluster(2, NetModel::ideal(), |comm| {
            let map: DistHashMap<String, u64> =
                DistHashMap::new(comm.rank, 2, 2, HashKind::Fx, CombineMode::None);
            for _ in 0..3 {
                for w in ["alpha", "beta", "gamma", "delta"] {
                    map.upsert(0, w.to_string(), 1, reducer::sum);
                }
            }
            let stats = map.shuffle(comm, reducer::sum, true);
            (stats, map.to_vec_local())
        });
        let mut total: HashMap<String, u64> = HashMap::new();
        let mut wire = crate::util::ser::DictStats::default();
        for (stats, entries) in results {
            wire = wire.merged(&stats);
            for (k, v) in entries {
                *total.entry(k).or_insert(0) += v;
            }
        }
        // Each key is remote for exactly one of the two nodes, so across
        // the cluster every key registers once and back-references twice.
        assert_eq!(wire.unique, 4, "{wire:?}");
        assert_eq!(wire.refs, 8, "{wire:?}");
        assert!(wire.key_enc_bytes < wire.key_raw_bytes, "{wire:?}");
        assert_eq!(total.len(), 4);
        assert!(total.values().all(|&c| c == 6)); // 3 per node × 2 nodes
    }

    #[test]
    fn integer_keyed_map() {
        let results = spawn_cluster(2, NetModel::ideal(), |comm| {
            let map: DistHashMap<u32, u64> =
                DistHashMap::new(comm.rank, 2, 2, HashKind::Fx, CombineMode::Eager);
            for i in 0..50u32 {
                map.upsert(0, i % 5, 1, reducer::sum);
            }
            let stats = map.shuffle(comm, reducer::sum, true);
            // Integer keys have no dictionary form — stats must stay zero.
            assert!(stats.is_zero(), "{stats:?}");
            map.to_vec_local()
        });
        let merged: HashMap<u32, u64> = results.into_iter().flatten().collect();
        assert_eq!(merged.len(), 5);
        assert!(merged.values().all(|&c| c == 20)); // 10 per node × 2 nodes
    }
}
