//! `DistHashMap` — the paper's distributed hash map.
//!
//! Every node holds a local [`ConcurrentHashMap`]; keys are owner-sharded
//! by hash (`owner = bucket_of(hash(key), nnodes)`, the same high-bits
//! bucketing the single-node segments use). During the map phase each node
//! upserts whatever its mappers emit — for keys it owns *and* keys it
//! doesn't — and the local map combines continuously ([`CombineMode::Eager`],
//! the paper's "local reduce during the map phase"). One
//! [`DistHashMap::shuffle`] then re-shards: each node serializes the
//! entries it doesn't own, all-to-all exchanges them over the simulated
//! fabric (bytes measured on the wire), and merges what it receives, after
//! which every key lives exactly once, on its owner.
//!
//! With [`CombineMode::None`] the map phase instead buffers every raw
//! `(K, V)` emission per thread and the shuffle ships them all — the
//! ablation that quantifies the paper's local-reduce claim.
//!
//! The map phase itself can be memory-bounded
//! ([`DistHashMap::with_map_bound`]): beyond a spill threshold of
//! estimated in-flight bytes, pending entries drain into owner-bucketed
//! encoded frames parked on the disk tier, and the next shuffle ships
//! each owner's parked frames ahead of the fresh payload (every frame is
//! self-delimiting, so receivers just keep decoding). This closes the
//! ROADMAP 2b hole where `--spill-threshold` bounded only the
//! reduce-side merge while the map-side combine grew without limit.

use std::sync::atomic::{AtomicU64, Ordering::Relaxed};
use std::sync::{Arc, Mutex};

use crate::cache::CacheKey;
use crate::cluster::Comm;
use crate::concurrent::{default_segments, CachePolicy, ConcurrentHashMap, MapKey, MapValue};
use crate::hash::{bucket_of, HashKind};
use crate::storage::{fresh_spill_namespace, BlockStore, DiskTier, ExternalMerger, HeapSize};
use crate::trace::{self, SpanCat};
use crate::util::ser::{
    decode_varint, encode_pairs, DataKey, Decode, DictReader, DictStats, Encode, Reader,
};

use super::CombineMode;

/// Conservative per-pair bookkeeping overhead (hash + table slot) added
/// to the heap estimate when charging the map-phase budget.
const PAIR_OVERHEAD: u64 = 32;

/// Map-phase spill state (see the module docs): a byte budget, the disk
/// tier frames park on, and the per-owner frame keys awaiting the next
/// shuffle. Attached only on shuffle stages — an elided stage's map
/// output *is* the job result, so there is nothing to bound there.
struct MapBound {
    threshold: u64,
    disk: Arc<DiskTier>,
    dict: bool,
    /// Frame namespace on `disk` (fresh per map, like a merger's runs).
    namespace: u64,
    /// Estimated heap bytes upserted since the last spill.
    bytes: AtomicU64,
    /// Next frame id (the block key's partition field).
    seq: AtomicU64,
    /// Single-spiller gate: contenders skip — their bytes are already
    /// charged, so the winner's drain covers them.
    gate: Mutex<()>,
    /// Per-owner spilled frame keys, in write order.
    frames: Mutex<Vec<Vec<CacheKey>>>,
    /// Dictionary stats accumulated across spilled frames.
    stats: Mutex<DictStats>,
}

pub struct DistHashMap<K: MapKey, V: MapValue> {
    rank: usize,
    nnodes: usize,
    nthreads: usize,
    hash: HashKind,
    combine: CombineMode,
    /// Local table: pending (pre-shuffle) entries under `Eager`, and the
    /// owned shard after a shuffle in either mode.
    local: ConcurrentHashMap<K, V>,
    /// Per-thread raw emission buffers (`CombineMode::None` only).
    raw: Vec<Mutex<Vec<(K, V)>>>,
    /// Map-phase spill budget, when bounded.
    bound: Option<MapBound>,
}

impl<K: MapKey, V: MapValue> DistHashMap<K, V> {
    pub fn new(
        rank: usize,
        nnodes: usize,
        nthreads: usize,
        hash: HashKind,
        combine: CombineMode,
    ) -> Self {
        Self::with_policy(rank, nnodes, nthreads, hash, combine, CachePolicy::default())
    }

    pub fn with_policy(
        rank: usize,
        nnodes: usize,
        nthreads: usize,
        hash: HashKind,
        combine: CombineMode,
        policy: CachePolicy,
    ) -> Self {
        assert!(nnodes > 0 && rank < nnodes && nthreads > 0);
        Self {
            rank,
            nnodes,
            nthreads,
            hash,
            combine,
            local: ConcurrentHashMap::with_policy(
                default_segments(nthreads),
                nthreads,
                hash,
                policy,
            ),
            raw: (0..nthreads).map(|_| Mutex::new(Vec::new())).collect(),
            bound: None,
        }
    }

    /// Attach the map-phase spill budget: beyond `threshold` estimated
    /// in-flight bytes, [`upsert_spillable`](Self::upsert_spillable)
    /// parks pending entries on `disk` as owner-bucketed frames until the
    /// shuffle ships them.
    pub fn with_map_bound(mut self, threshold: u64, disk: Arc<DiskTier>, dict: bool) -> Self {
        self.bound = Some(MapBound {
            threshold,
            disk,
            dict,
            namespace: fresh_spill_namespace(),
            bytes: AtomicU64::new(0),
            seq: AtomicU64::new(0),
            gate: Mutex::new(()),
            frames: Mutex::new((0..self.nnodes).map(|_| Vec::new()).collect()),
            stats: Mutex::new(DictStats::default()),
        });
        self
    }

    pub fn rank(&self) -> usize {
        self.rank
    }

    pub fn nnodes(&self) -> usize {
        self.nnodes
    }

    pub fn combine_mode(&self) -> CombineMode {
        self.combine
    }

    /// Which rank owns `key` after the shuffle.
    pub fn owner_of(&self, key: &K) -> usize {
        bucket_of(key.hash_with(self.hash), self.nnodes)
    }

    /// Map-phase insert from worker thread `tid`. Under `Eager` the value
    /// combines into the local map immediately; under `None` the raw pair
    /// is buffered for the shuffle.
    #[inline]
    pub fn upsert(&self, tid: usize, key: K, value: V, reduce: impl Fn(&mut V, V)) {
        match self.combine {
            CombineMode::Eager => self.local.upsert(tid, key, value, reduce),
            CombineMode::None => self.raw[tid].lock().unwrap().push((key, value)),
        }
    }

    /// Entries currently owned locally. Complete only after
    /// [`shuffle`](Self::shuffle); the per-node shards are disjoint, so
    /// concatenating every node's `to_vec_local` yields the global result.
    pub fn to_vec_local(&self) -> Vec<(K, V)> {
        self.local.to_vec()
    }

    /// Make this node's emissions readable **without** the exchange — the
    /// zero-shuffle fast path for workloads whose keys never need
    /// co-location (each key emitted at most once globally). Thread caches
    /// are synced into the local table; under [`CombineMode::None`] the raw
    /// per-thread buffers are folded in first. Unlike
    /// [`shuffle`](Self::shuffle), entries stay on the node that produced
    /// them (still globally disjoint under the uniqueness contract) and
    /// nothing touches the fabric.
    pub fn settle_local(&self, reduce: impl Fn(&mut V, V) + Sync) {
        if let Some(b) = &self.bound {
            debug_assert!(
                b.frames.lock().unwrap().iter().all(Vec::is_empty),
                "settle_local would lose parked map-spill frames; \
                 elided stages must not attach a map bound"
            );
        }
        if self.combine == CombineMode::None {
            for cell in &self.raw {
                for (k, v) in cell.lock().unwrap().drain(..) {
                    self.local.upsert(0, k, v, &reduce);
                }
            }
        }
        self.local.sync(self.nthreads, &reduce);
    }

    /// Drain pending entries (thread caches or raw buffers) into
    /// owner-sharded buckets — step 1+2 of either shuffle flavor.
    fn drain_by_owner(&self, reduce: &(impl Fn(&mut V, V) + Sync)) -> Vec<Vec<(K, V)>> {
        let n = self.nnodes;
        let mut pending: Vec<(u64, K, V)> = Vec::new();
        match self.combine {
            CombineMode::Eager => {
                self.local.sync(self.nthreads, reduce);
                for e in self.local.drain_entries() {
                    pending.push((e.hash, e.key, e.value));
                }
            }
            CombineMode::None => {
                for cell in &self.raw {
                    for (k, v) in cell.lock().unwrap().drain(..) {
                        let h = k.hash_with(self.hash);
                        pending.push((h, k, v));
                    }
                }
            }
        }
        let mut by_owner: Vec<Vec<(K, V)>> = (0..n).map(|_| Vec::new()).collect();
        for (h, k, v) in pending {
            by_owner[bucket_of(h, n)].push((k, v));
        }
        by_owner
    }

    /// Take this map's parked spill frames, read back per owner, plus the
    /// dictionary stats their encoding accumulated. `None` when no bound
    /// is attached or nothing spilled. Blocks are deleted as they are
    /// consumed.
    fn take_spilled_frames(&self) -> Option<(Vec<Vec<Vec<u8>>>, DictStats)> {
        let b = self.bound.as_ref()?;
        let mut frames = b.frames.lock().unwrap();
        if frames.iter().all(Vec::is_empty) {
            return None;
        }
        let out = frames
            .iter_mut()
            .map(|keys| {
                keys.drain(..)
                    .map(|key| {
                        let buf = b
                            .disk
                            .read(&key)
                            .expect("map-spill frame read")
                            .expect("map-spill frame missing");
                        b.disk.delete(&key);
                        buf
                    })
                    .collect()
            })
            .collect();
        Some((out, std::mem::take(&mut *b.stats.lock().unwrap())))
    }

    /// Prepend `dst`'s parked frames to its fresh payload (frames are
    /// self-delimiting, so the receiver just keeps decoding).
    fn frames_plus(
        spilled: &Option<(Vec<Vec<Vec<u8>>>, DictStats)>,
        dst: usize,
        fresh: Vec<u8>,
    ) -> Vec<u8> {
        match spilled {
            Some((frames, _)) if !frames[dst].is_empty() => {
                let mut payload = Vec::with_capacity(
                    frames[dst].iter().map(Vec::len).sum::<usize>() + fresh.len(),
                );
                for f in &frames[dst] {
                    payload.extend_from_slice(f);
                }
                payload.extend_from_slice(&fresh);
                payload
            }
            _ => fresh,
        }
    }

    /// The all-to-all re-shard: collect every pending entry, ship each to
    /// its owner (self-delivery stays typed and off the wire), merge what
    /// arrives. After this, the map holds exactly this rank's shard.
    ///
    /// Wire payloads carry dictionary-encoded keys when `dict` is on:
    /// each repeated key crosses the fabric once, later occurrences as a
    /// varint back-reference (the [`crate::util::ser::DictWriter`]
    /// format). The receive path decodes into per-payload
    /// [`DictReader`] arenas and upserts through borrowed key handles,
    /// materializing an owned key only on first sight. Returns the
    /// outgoing-payload dictionary stats.
    pub fn shuffle(
        &self,
        comm: &Comm,
        reduce: impl Fn(&mut V, V) + Sync,
        dict: bool,
    ) -> DictStats
    where
        K: DataKey,
        V: Encode + Decode,
    {
        assert_eq!(comm.nnodes(), self.nnodes, "comm/map cluster size mismatch");
        let mut by_owner = self.drain_by_owner(&reduce);
        let spilled = self.take_spilled_frames();

        // 3. Exchange. The local shard bypasses serialization and the
        //    wire — that asymmetry is the measurable local-reduce saving.
        //    Parked map-spill frames ride ahead of each fresh payload.
        let mine = std::mem::take(&mut by_owner[self.rank]);
        let mut stats = spilled.as_ref().map(|(_, s)| *s).unwrap_or_default();
        let outgoing: Vec<Vec<u8>> = by_owner
            .iter()
            .enumerate()
            .map(|(dst, shard)| {
                if dst == self.rank {
                    return Vec::new();
                }
                let (bytes, s) = encode_pairs(shard, dict);
                stats = stats.merged(&s);
                Self::frames_plus(&spilled, dst, bytes)
            })
            .collect();
        let incoming = comm.all_to_all(outgoing);

        // 4. Merge own + received into the (now empty) local table. A
        //    payload is a sequence of self-delimiting frames, each with
        //    its own dictionary arena.
        for (k, v) in mine {
            self.local.upsert(0, k, v, &reduce);
        }
        let absorb = |buf: &[u8]| {
            let mut r = Reader::new(buf);
            while !r.is_empty() {
                let mut ctx = DictReader::new();
                let count = decode_varint(&mut r).expect("dist shuffle decode");
                for _ in 0..count {
                    let kr = K::dict_decode(&mut r, &mut ctx).expect("dist shuffle decode");
                    let v = V::decode(&mut r).expect("dist shuffle decode");
                    let h = K::ref_hash(&kr, &ctx, self.hash);
                    self.local.upsert_borrowed(
                        0,
                        h,
                        |k: &K| K::ref_eq_owned(&kr, &ctx, k),
                        || K::ref_materialize(&kr, &ctx),
                        v,
                        &reduce,
                    );
                }
            }
        };
        if let Some((frames, _)) = &spilled {
            for buf in &frames[self.rank] {
                absorb(buf);
            }
        }
        for (src, buf) in incoming.into_iter().enumerate() {
            if src == self.rank {
                continue;
            }
            absorb(&buf);
        }
        self.local.sync(self.nthreads, &reduce);
        stats
    }

    /// [`shuffle`](Self::shuffle) with a **bounded-memory merge**: the
    /// exchange is identical (same drain, same owner sharding, same bytes
    /// on the fabric), but the reduce-side merge runs through an
    /// [`ExternalMerger`] — beyond `threshold` estimated in-flight bytes
    /// the partial shard sort-and-spills runs to `disk`, and the merged
    /// shard comes back from a loser-tree external merge. Returns this
    /// node's merged entries (the local table is left drained): for any
    /// associative + commutative `reduce` the result set is identical to
    /// the in-memory shuffle at any threshold down to 0.
    pub fn shuffle_external(
        &self,
        comm: &Comm,
        reduce: impl Fn(&mut V, V) + Sync,
        threshold: u64,
        disk: &Arc<DiskTier>,
        dict: bool,
    ) -> (Vec<(K, V)>, DictStats)
    where
        K: Ord + DataKey + HeapSize,
        V: Encode + Decode + HeapSize,
    {
        assert_eq!(comm.nnodes(), self.nnodes, "comm/map cluster size mismatch");
        let mut by_owner = self.drain_by_owner(&reduce);
        let spilled = self.take_spilled_frames();

        // 3. Exchange — byte-for-byte the same protocol as `shuffle`
        //    (parked map-spill frames ride ahead of each fresh payload).
        let mine = std::mem::take(&mut by_owner[self.rank]);
        let mut stats = spilled.as_ref().map(|(_, s)| *s).unwrap_or_default();
        let outgoing: Vec<Vec<u8>> = by_owner
            .iter()
            .enumerate()
            .map(|(dst, shard)| {
                if dst == self.rank {
                    return Vec::new();
                }
                let (bytes, s) = encode_pairs(shard, dict);
                stats = stats.merged(&s);
                Self::frames_plus(&spilled, dst, bytes)
            })
            .collect();
        let incoming = comm.all_to_all(outgoing);

        // 4. Merge own + received through the budgeted external merger.
        // Received keys stay borrowed handles into the payload's
        // dictionary arena until the merger actually needs an owned key
        // (first sight of the key, or a spill re-materialization).
        let mut merger: ExternalMerger<K, V> = ExternalMerger::new(
            threshold,
            Arc::clone(disk) as Arc<dyn BlockStore>,
            Arc::clone(disk.counters()),
            fresh_spill_namespace(),
        )
        .with_dict_keys(dict);
        for (k, v) in mine {
            merger.insert(k, v, &reduce);
        }
        let mut absorb = |buf: &[u8]| {
            let mut r = Reader::new(buf);
            while !r.is_empty() {
                let mut ctx = DictReader::new();
                let count = decode_varint(&mut r).expect("dist shuffle decode");
                for _ in 0..count {
                    let kr = K::dict_decode(&mut r, &mut ctx).expect("dist shuffle decode");
                    let v = V::decode(&mut r).expect("dist shuffle decode");
                    merger.insert_ref(kr, &ctx, v, &reduce);
                }
            }
        };
        if let Some((frames, _)) = &spilled {
            for buf in &frames[self.rank] {
                absorb(buf);
            }
        }
        for (src, buf) in incoming.into_iter().enumerate() {
            if src == self.rank {
                continue;
            }
            absorb(&buf);
        }
        drop(absorb);
        (merger.finish(&reduce), stats)
    }
}

/// The budgeted map phase. The spill path encodes pending pairs into
/// disk frames, so these methods carry the full data-key bounds — every
/// [`crate::mapreduce::Workload`] key/value type already satisfies them.
impl<K, V> DistHashMap<K, V>
where
    K: MapKey + DataKey + HeapSize,
    V: MapValue + Encode + HeapSize,
{
    /// [`upsert`](Self::upsert) that charges the map-phase budget and
    /// spills pending entries to disk past the bound's threshold. Plain
    /// upsert when no bound is attached.
    #[inline]
    pub fn upsert_spillable(&self, tid: usize, key: K, value: V, reduce: impl Fn(&mut V, V)) {
        let est = if self.bound.is_some() {
            (key.heap_bytes() + value.heap_bytes()) as u64 + PAIR_OVERHEAD
        } else {
            0
        };
        self.upsert(tid, key, value, reduce);
        self.charge(est);
    }

    /// Charge `est` freshly upserted bytes against the bound; spill once
    /// over threshold. The estimate deliberately counts combined-in-place
    /// upserts too (over-counting only spills earlier, never later, so
    /// the bound holds).
    #[inline]
    fn charge(&self, est: u64) {
        if let Some(b) = &self.bound {
            if b.bytes.fetch_add(est, Relaxed) + est > b.threshold {
                self.spill_pending();
            }
        }
    }

    /// Drain pending entries (thread caches + segments, or the raw
    /// buffers) into owner-bucketed encoded frames on the disk tier. One
    /// spiller at a time; contenders return immediately — their bytes are
    /// already charged, so the winner's drain covers them.
    fn spill_pending(&self) {
        let Some(b) = &self.bound else { return };
        let Ok(_gate) = b.gate.try_lock() else { return };
        if b.bytes.load(Relaxed) <= b.threshold {
            return; // another spiller just drained
        }
        let n = self.nnodes;
        let mut by_owner: Vec<Vec<(K, V)>> = (0..n).map(|_| Vec::new()).collect();
        match self.combine {
            CombineMode::Eager => {
                for e in self.local.drain_all() {
                    by_owner[bucket_of(e.hash, n)].push((e.key, e.value));
                }
            }
            CombineMode::None => {
                for cell in &self.raw {
                    for (k, v) in cell.lock().unwrap().drain(..) {
                        let h = k.hash_with(self.hash);
                        by_owner[bucket_of(h, n)].push((k, v));
                    }
                }
            }
        }
        b.bytes.store(0, Relaxed);
        let mut frames = b.frames.lock().unwrap();
        for (owner, shard) in by_owner.iter().enumerate() {
            if shard.is_empty() {
                continue;
            }
            let (bytes, s) = encode_pairs(shard, b.dict);
            let _sp = trace::span_arg(SpanCat::SpillRun, "map-spill", bytes.len() as u64);
            let key = CacheKey {
                namespace: b.namespace,
                generation: 0,
                partition: b.seq.fetch_add(1, Relaxed),
                splits: 0,
            };
            match b.disk.write(key, &bytes) {
                Ok(_) => {
                    b.disk.counters().record_spill(bytes.len() as u64);
                    frames[owner].push(key);
                    let mut stats = b.stats.lock().unwrap();
                    *stats = stats.merged(&s);
                }
                Err(_) => b.disk.counters().record_spill_failure(),
            }
        }
    }
}

impl<V: MapValue> DistHashMap<String, V> {
    /// Borrowed-key upsert — the zero-alloc "TCM" hot path: the owned key
    /// is materialized only when the token is seen for the first time.
    #[inline]
    pub fn upsert_str(&self, tid: usize, key: &str, value: V, reduce: impl Fn(&mut V, V)) {
        match self.combine {
            CombineMode::Eager => {
                let hash = self.hash.hash(key.as_bytes());
                self.local.upsert_borrowed(
                    tid,
                    hash,
                    |k: &String| k == key,
                    || key.to_string(),
                    value,
                    reduce,
                );
            }
            CombineMode::None => self.raw[tid].lock().unwrap().push((key.to_string(), value)),
        }
    }
}

impl<V> DistHashMap<String, V>
where
    V: MapValue + Encode + HeapSize,
{
    /// Borrowed-key [`upsert_str`](Self::upsert_str) with the map-phase
    /// budget charge (see [`upsert_spillable`](Self::upsert_spillable)).
    #[inline]
    pub fn upsert_str_spillable(
        &self,
        tid: usize,
        key: &str,
        value: V,
        reduce: impl Fn(&mut V, V),
    ) {
        let est = if self.bound.is_some() {
            // Mirrors `String`'s `HeapSize` (len + 24) without owning.
            (key.len() + 24 + value.heap_bytes()) as u64 + PAIR_OVERHEAD
        } else {
            0
        };
        self.upsert_str(tid, key, value, reduce);
        self.charge(est);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{spawn_cluster, NetModel};
    use crate::dist::reducer;
    use std::collections::HashMap;

    fn count_words(
        nnodes: usize,
        combine: CombineMode,
        words: &[&str],
        dict: bool,
    ) -> HashMap<String, u64> {
        let results = spawn_cluster(nnodes, NetModel::ideal(), |comm| {
            let map: DistHashMap<String, u64> =
                DistHashMap::new(comm.rank, nnodes, 2, HashKind::Fx, combine);
            // Every node inserts the full stream.
            for w in words {
                map.upsert(0, w.to_string(), 1, reducer::sum);
            }
            map.shuffle(comm, reducer::sum, dict);
            map.to_vec_local()
        });
        results.into_iter().flatten().collect()
    }

    #[test]
    fn shuffle_shards_and_totals() {
        let words = ["a", "b", "a", "c", "a", "b"];
        for combine in [CombineMode::Eager, CombineMode::None] {
            for nnodes in [1usize, 2, 3] {
                for dict in [true, false] {
                    let counts = count_words(nnodes, combine, &words, dict);
                    assert_eq!(counts.len(), 3, "{combine:?} nnodes={nnodes} dict={dict}");
                    assert_eq!(counts["a"], 3 * nnodes as u64);
                    assert_eq!(counts["b"], 2 * nnodes as u64);
                    assert_eq!(counts["c"], nnodes as u64);
                }
            }
        }
    }

    #[test]
    fn owned_keys_land_on_owner() {
        let nnodes = 4;
        let results = spawn_cluster(nnodes, NetModel::ideal(), |comm| {
            let map: DistHashMap<String, u64> =
                DistHashMap::new(comm.rank, nnodes, 2, HashKind::Fx, CombineMode::Eager);
            for i in 0..100 {
                map.upsert(0, format!("k{i}"), 1, reducer::sum);
            }
            map.shuffle(comm, reducer::sum, true);
            let owned = map.to_vec_local();
            owned.iter().all(|(k, _)| map.owner_of(k) == comm.rank)
        });
        assert!(results.into_iter().all(|ok| ok));
    }

    #[test]
    fn upsert_str_matches_owned() {
        let words = ["x", "y", "x"];
        let results = spawn_cluster(2, NetModel::ideal(), |comm| {
            let a: DistHashMap<String, u64> =
                DistHashMap::new(comm.rank, 2, 2, HashKind::Fx, CombineMode::Eager);
            let b: DistHashMap<String, u64> =
                DistHashMap::new(comm.rank, 2, 2, HashKind::Fx, CombineMode::Eager);
            for w in words {
                a.upsert(0, w.to_string(), 1, reducer::sum);
                b.upsert_str(0, w, 1, reducer::sum);
            }
            a.shuffle(comm, reducer::sum, true);
            b.shuffle(comm, reducer::sum, false);
            let mut av = a.to_vec_local();
            let mut bv = b.to_vec_local();
            av.sort();
            bv.sort();
            (av, bv)
        });
        for (av, bv) in results {
            assert_eq!(av, bv);
        }
    }

    #[test]
    fn shuffle_external_matches_in_memory_shuffle() {
        use crate::storage::DiskTier;
        let words = ["a", "b", "a", "c", "a", "b"];
        for combine in [CombineMode::Eager, CombineMode::None] {
            // Thresholds bracketing the spectrum: spill-everything and
            // never-spill must both match the plain shuffle.
            for threshold in [0u64, u64::MAX] {
                let results = spawn_cluster(2, NetModel::ideal(), |comm| {
                    let map: DistHashMap<String, u64> =
                        DistHashMap::new(comm.rank, 2, 2, HashKind::Fx, combine);
                    for w in words {
                        map.upsert(0, w.to_string(), 1, reducer::sum);
                    }
                    let disk = Arc::new(DiskTier::new(None));
                    let (merged, _) =
                        map.shuffle_external(comm, reducer::sum, threshold, &disk, true);
                    let spilled = disk.counters().snapshot().spilled_bytes;
                    (merged, spilled)
                });
                let mut spilled_total = 0;
                let merged: HashMap<String, u64> = results
                    .into_iter()
                    .flat_map(|(entries, spilled)| {
                        spilled_total += spilled;
                        entries
                    })
                    .collect();
                assert_eq!(merged.len(), 3, "{combine:?} threshold={threshold}");
                assert_eq!(merged["a"], 6);
                assert_eq!(merged["b"], 4);
                assert_eq!(merged["c"], 2);
                if threshold == 0 {
                    assert!(spilled_total > 0, "threshold 0 must spill ({combine:?})");
                } else {
                    assert_eq!(spilled_total, 0, "unbounded never spills ({combine:?})");
                }
            }
        }
    }

    #[test]
    fn dict_wire_stats_count_repeats() {
        // Two nodes, every key emitted 3x under CombineMode::None, so the
        // wire shard for the remote owner carries repeated keys — the
        // dictionary must register each unique key once and back-reference
        // the rest, and the encoded key bytes must shrink.
        let results = spawn_cluster(2, NetModel::ideal(), |comm| {
            let map: DistHashMap<String, u64> =
                DistHashMap::new(comm.rank, 2, 2, HashKind::Fx, CombineMode::None);
            for _ in 0..3 {
                for w in ["alpha", "beta", "gamma", "delta"] {
                    map.upsert(0, w.to_string(), 1, reducer::sum);
                }
            }
            let stats = map.shuffle(comm, reducer::sum, true);
            (stats, map.to_vec_local())
        });
        let mut total: HashMap<String, u64> = HashMap::new();
        let mut wire = crate::util::ser::DictStats::default();
        for (stats, entries) in results {
            wire = wire.merged(&stats);
            for (k, v) in entries {
                *total.entry(k).or_insert(0) += v;
            }
        }
        // Each key is remote for exactly one of the two nodes, so across
        // the cluster every key registers once and back-references twice.
        assert_eq!(wire.unique, 4, "{wire:?}");
        assert_eq!(wire.refs, 8, "{wire:?}");
        assert!(wire.key_enc_bytes < wire.key_raw_bytes, "{wire:?}");
        assert_eq!(total.len(), 4);
        assert!(total.values().all(|&c| c == 6)); // 3 per node × 2 nodes
    }

    #[test]
    fn integer_keyed_map() {
        let results = spawn_cluster(2, NetModel::ideal(), |comm| {
            let map: DistHashMap<u32, u64> =
                DistHashMap::new(comm.rank, 2, 2, HashKind::Fx, CombineMode::Eager);
            for i in 0..50u32 {
                map.upsert(0, i % 5, 1, reducer::sum);
            }
            let stats = map.shuffle(comm, reducer::sum, true);
            // Integer keys have no dictionary form — stats must stay zero.
            assert!(stats.is_zero(), "{stats:?}");
            map.to_vec_local()
        });
        let merged: HashMap<u32, u64> = results.into_iter().flatten().collect();
        assert_eq!(merged.len(), 5);
        assert!(merged.values().all(|&c| c == 20)); // 10 per node × 2 nodes
    }
}
