//! Stock reducers (the paper's `Reducer<T>::sum` family).
//!
//! A reducer folds one incoming value into an accumulator in place. Every
//! reducer used through the stack must be **associative and commutative**:
//! the engines fold in whatever order threads, caches, and shuffles happen
//! to deliver values, and the eventual-consistency contract of
//! [`crate::concurrent::ConcurrentHashMap`] depends on order independence.

/// `acc += v` — the word-count reducer.
#[inline]
pub fn sum<T: std::ops::AddAssign>(acc: &mut T, v: T) {
    *acc += v;
}

/// Keep the maximum.
#[inline]
pub fn max<T: Ord>(acc: &mut T, v: T) {
    if v > *acc {
        *acc = v;
    }
}

/// Keep the minimum.
#[inline]
pub fn min<T: Ord>(acc: &mut T, v: T) {
    if v < *acc {
        *acc = v;
    }
}

/// Concatenate lists (associative; commutative up to element order, so
/// callers that need determinism sort at finalize time — see
/// `workloads::InvertedIndex`).
#[inline]
pub fn concat<T>(acc: &mut Vec<T>, mut more: Vec<T>) {
    acc.append(&mut more);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sum_accumulates() {
        let mut a = 3u64;
        sum(&mut a, 4);
        assert_eq!(a, 7);
    }

    #[test]
    fn max_min_keep_extremes() {
        let mut a = 5i64;
        max(&mut a, 9);
        max(&mut a, 2);
        assert_eq!(a, 9);
        let mut b = 5i64;
        min(&mut b, 9);
        min(&mut b, 2);
        assert_eq!(b, 2);
    }

    #[test]
    fn concat_appends() {
        let mut a = vec![1u32, 2];
        concat(&mut a, vec![3, 4]);
        assert_eq!(a, vec![1, 2, 3, 4]);
    }
}
