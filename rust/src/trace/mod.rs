//! Structured tracing: per-thread span timelines behind a process-global
//! on/off switch.
//!
//! The instrumentation problem this solves: spans are emitted from deep
//! inside the storage tiers, the spill merger and the executor's worker
//! loop — layers that never see a `JobSpec` — so the recording channel
//! cannot be a handle threaded through APIs. Instead there is one
//! process-global **session** slot:
//!
//! * With no session installed, every probe ([`span`], [`counter`]) is a
//!   single relaxed atomic load and an early return — near-zero cost, no
//!   allocation, no clock read. This is the permanent state of normal
//!   runs; tracing exists only while a [`TraceSession`] is alive.
//! * With a session installed, each thread lazily registers a private
//!   buffer ([`capacity`](TraceSession::start_with_capacity)-bounded;
//!   overflow is counted, not grown) and appends completed spans to it.
//!   Appends take an uncontended per-thread lock — the only other
//!   contender is the end-of-job drain — so the hot path is a TLS read, a
//!   clock read and a `Vec::push`.
//!
//! Spans are recorded **complete** (start + duration, captured when the
//! guard drops), which keeps the timeline well-formed by construction:
//! there is no unbalanced begin/end to repair at export time. The
//! determinism contract of the engines is untouched — probes read clocks
//! and write side buffers, they never influence scheduling or results, so
//! traced runs stay bit-identical to untraced ones.
//!
//! [`chrome`] renders a drained [`Trace`] as Chrome trace-event JSON
//! (open in Perfetto or `chrome://tracing`); [`profile`] folds it into
//! the per-stage phase breakdown behind `blaze profile`;
//! [`metrics`] holds the typed [`MetricSet`] that replaced the stringly
//! report details. Span taxonomy: see [`SpanCat`] (one variant per
//! instrumented subsystem event).
//!
//! Concurrency note: sessions are process-global and **last-start wins**
//! — two overlapping sessions do not interleave correctly (each thread
//! records into the newest one). The CLI holds at most one; tests
//! serialize through a shared lock.

pub mod chrome;
pub mod metrics;
pub mod profile;

pub use metrics::{MetricSet, MetricValue};

use std::cell::RefCell;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering::Relaxed};
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// What a span measures — one variant per instrumented event kind. The
/// taxonomy table in the README mirrors this enum.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum SpanCat {
    /// One engine stage attempt (`arg` = stage id).
    Stage,
    /// A node's map phase (`arg` = node rank).
    Map,
    /// A node's shuffle/exchange phase (`arg` = node rank).
    Exchange,
    /// Shard finalization (`arg` = node rank).
    Finalize,
    /// One executor task (a parse/map chunk or a stage partition).
    Task,
    /// One sorted run written by the external merger (`arg` = run bytes).
    SpillRun,
    /// The loser-tree merge of all spilled runs (`arg` = run count).
    SpillMerge,
    /// A memory-tier victim demoted to disk (`arg` = bytes).
    Demote,
    /// A disk block promoted back into memory (`arg` = bytes).
    Promote,
    /// Block compression on the disk-tier write path (`arg` = raw bytes).
    Compress,
    /// Frame decompression on the disk-tier read path (`arg` = raw bytes).
    Decompress,
    /// A memory-tier cache lookup.
    CacheLookup,
    /// Driver-side work between chained stages (render + re-ingest).
    Bridge,
    /// One round of an iterative job (`arg` = round index).
    Round,
    /// Driver-side `advance`/state fold of an iterative round.
    Driver,
    /// Time a job's stage spent waiting for a scheduler slot (`arg` =
    /// tenant id).
    QueueWait,
    /// One admission decision by the job service (`arg` = tenant id).
    Admission,
    /// A fair-queue pick that bypassed an older waiter from another
    /// tenant (`arg` = the bypassed tenant's id).
    Preemption,
}

impl SpanCat {
    /// Stable label (Chrome `cat` field, profile table rows).
    pub fn label(self) -> &'static str {
        match self {
            SpanCat::Stage => "stage",
            SpanCat::Map => "map",
            SpanCat::Exchange => "exchange",
            SpanCat::Finalize => "finalize",
            SpanCat::Task => "task",
            SpanCat::SpillRun => "spill-run",
            SpanCat::SpillMerge => "spill-merge",
            SpanCat::Demote => "demote",
            SpanCat::Promote => "promote",
            SpanCat::Compress => "compress",
            SpanCat::Decompress => "decompress",
            SpanCat::CacheLookup => "cache-lookup",
            SpanCat::Bridge => "bridge",
            SpanCat::Round => "round",
            SpanCat::Driver => "driver",
            SpanCat::QueueWait => "queue-wait",
            SpanCat::Admission => "admission",
            SpanCat::Preemption => "preemption",
        }
    }
}

/// One completed span on one thread. Times are nanoseconds since the
/// session epoch.
#[derive(Clone, Copy, Debug)]
pub struct SpanEvent {
    pub cat: SpanCat,
    pub name: &'static str,
    /// Category-specific payload (stage id, node rank, bytes, …).
    pub arg: u64,
    pub t0_ns: u64,
    pub dur_ns: u64,
}

/// One sample of a monotonic-time counter track (cache bytes, queue
/// depth).
#[derive(Clone, Copy, Debug)]
pub struct CounterEvent {
    pub name: &'static str,
    pub t_ns: u64,
    pub value: u64,
}

/// Everything one thread recorded during a session.
#[derive(Clone, Debug)]
pub struct ThreadTrace {
    /// Dense per-session thread index (Chrome `tid`).
    pub tid: u64,
    /// OS thread name at registration (`blaze-exec-3`, `main`, …).
    pub name: String,
    pub spans: Vec<SpanEvent>,
    pub counters: Vec<CounterEvent>,
    /// Events discarded because the buffer hit its capacity.
    pub dropped: u64,
}

/// A drained session: per-thread timelines, ready for
/// [`chrome::render`] or [`profile::analyze`].
#[derive(Clone, Debug, Default)]
pub struct Trace {
    pub threads: Vec<ThreadTrace>,
}

impl Trace {
    /// Total spans across all threads.
    pub fn span_count(&self) -> usize {
        self.threads.iter().map(|t| t.spans.len()).sum()
    }

    /// Total events discarded to capacity limits across all threads.
    pub fn dropped(&self) -> u64 {
        self.threads.iter().map(|t| t.dropped).sum()
    }
}

/// Default per-thread event capacity (spans + counters each).
const DEFAULT_CAPACITY: usize = 1 << 18;

struct ThreadBuf {
    tid: u64,
    name: String,
    capacity: usize,
    spans: Mutex<Vec<SpanEvent>>,
    counters: Mutex<Vec<CounterEvent>>,
    dropped: AtomicU64,
}

struct SessionInner {
    generation: u64,
    epoch: Instant,
    capacity: usize,
    bufs: Mutex<Vec<Arc<ThreadBuf>>>,
}

impl SessionInner {
    fn register_thread(&self) -> Arc<ThreadBuf> {
        let name = std::thread::current()
            .name()
            .unwrap_or("thread")
            .to_string();
        let mut bufs = self.bufs.lock().unwrap();
        let buf = Arc::new(ThreadBuf {
            tid: bufs.len() as u64,
            name,
            capacity: self.capacity,
            spans: Mutex::new(Vec::new()),
            counters: Mutex::new(Vec::new()),
            dropped: AtomicU64::new(0),
        });
        bufs.push(Arc::clone(&buf));
        buf
    }
}

/// Fast-path gate: a single relaxed load on every probe.
static ENABLED: AtomicBool = AtomicBool::new(false);
/// Bumped per session so stale thread-local buffers re-register.
static GENERATION: AtomicU64 = AtomicU64::new(0);
static SESSION: Mutex<Option<Arc<SessionInner>>> = Mutex::new(None);

thread_local! {
    /// This thread's buffer in the current session (`generation` tags
    /// which session it belongs to).
    static LOCAL: RefCell<Option<(u64, Instant, Arc<ThreadBuf>)>> =
        const { RefCell::new(None) };
}

/// Is a session currently recording? One relaxed atomic load.
#[inline]
pub fn enabled() -> bool {
    ENABLED.load(Relaxed)
}

/// Run `f` with this thread's buffer + session epoch, registering with
/// the current session if needed. No-op when no session is installed.
fn with_local<R>(f: impl FnOnce(Instant, &ThreadBuf) -> R) -> Option<R> {
    let generation = GENERATION.load(Relaxed);
    LOCAL.with(|slot| {
        let mut slot = slot.borrow_mut();
        match slot.as_ref() {
            Some((g, epoch, buf)) if *g == generation => Some(f(*epoch, buf)),
            _ => {
                let session = SESSION.lock().unwrap().clone()?;
                if session.generation != generation {
                    // Raced with a start/finish; skip this event.
                    return None;
                }
                let buf = session.register_thread();
                let out = f(session.epoch, &buf);
                *slot = Some((generation, session.epoch, buf));
                Some(out)
            }
        }
    })
}

/// An in-flight span. Records a [`SpanEvent`] when dropped; a no-op when
/// tracing was disabled at creation.
pub struct Span {
    start: Option<Instant>,
    cat: SpanCat,
    name: &'static str,
    arg: u64,
}

impl Span {
    /// Attach/replace the category-specific payload before the span ends.
    pub fn set_arg(&mut self, arg: u64) {
        self.arg = arg;
    }
}

impl Drop for Span {
    fn drop(&mut self) {
        let Some(start) = self.start else { return };
        if !enabled() {
            return;
        }
        let dur_ns = start.elapsed().as_nanos() as u64;
        let (cat, name, arg) = (self.cat, self.name, self.arg);
        with_local(|epoch, buf| {
            let t0_ns = start.duration_since(epoch).as_nanos() as u64;
            let mut spans = buf.spans.lock().unwrap();
            if spans.len() < buf.capacity {
                spans.push(SpanEvent { cat, name, arg, t0_ns, dur_ns });
            } else {
                buf.dropped.fetch_add(1, Relaxed);
            }
        });
    }
}

/// Open a span of `cat`. Near-free when no session is recording.
#[inline]
pub fn span(cat: SpanCat, name: &'static str) -> Span {
    span_arg(cat, name, 0)
}

/// Open a span with a category-specific payload (stage id, bytes, …).
#[inline]
pub fn span_arg(cat: SpanCat, name: &'static str, arg: u64) -> Span {
    let start = if enabled() { Some(Instant::now()) } else { None };
    Span { start, cat, name, arg }
}

/// Sample a counter track (cache bytes, queue depth). Near-free when no
/// session is recording.
#[inline]
pub fn counter(name: &'static str, value: u64) {
    if !enabled() {
        return;
    }
    let now = Instant::now();
    with_local(|epoch, buf| {
        let t_ns = now.duration_since(epoch).as_nanos() as u64;
        let mut counters = buf.counters.lock().unwrap();
        if counters.len() < buf.capacity {
            counters.push(CounterEvent { name, t_ns, value });
        } else {
            buf.dropped.fetch_add(1, Relaxed);
        }
    });
}

/// A recording session. Install with [`start`](Self::start), run the
/// workload, then [`finish`](Self::finish) to drain every thread's
/// buffer into a [`Trace`].
pub struct TraceSession {
    inner: Arc<SessionInner>,
}

impl TraceSession {
    /// Install a session with the default per-thread capacity.
    pub fn start() -> TraceSession {
        Self::start_with_capacity(DEFAULT_CAPACITY)
    }

    /// Install a session whose per-thread buffers hold at most `capacity`
    /// spans (and counters) each; overflow increments
    /// [`ThreadTrace::dropped`]. Replaces any active session
    /// (last-start wins).
    pub fn start_with_capacity(capacity: usize) -> TraceSession {
        let generation = GENERATION.fetch_add(1, Relaxed) + 1;
        let inner = Arc::new(SessionInner {
            generation,
            epoch: Instant::now(),
            capacity: capacity.max(1),
            bufs: Mutex::new(Vec::new()),
        });
        *SESSION.lock().unwrap() = Some(Arc::clone(&inner));
        // Publish the generation before enabling so a probe that sees
        // `enabled` finds a matching session.
        GENERATION.store(generation, Relaxed);
        ENABLED.store(true, Relaxed);
        TraceSession { inner }
    }

    /// Stop recording and drain every registered thread's buffer.
    pub fn finish(self) -> Trace {
        {
            let mut session = SESSION.lock().unwrap();
            let ours = session
                .as_ref()
                .is_some_and(|s| s.generation == self.inner.generation);
            if ours {
                ENABLED.store(false, Relaxed);
                *session = None;
            }
        }
        let bufs = self.inner.bufs.lock().unwrap();
        let mut threads: Vec<ThreadTrace> = bufs
            .iter()
            .map(|b| ThreadTrace {
                tid: b.tid,
                name: b.name.clone(),
                spans: std::mem::take(&mut *b.spans.lock().unwrap()),
                counters: std::mem::take(&mut *b.counters.lock().unwrap()),
                dropped: b.dropped.load(Relaxed),
            })
            .collect();
        threads.retain(|t| !t.spans.is_empty() || !t.counters.is_empty() || t.dropped > 0);
        Trace { threads }
    }
}

#[cfg(test)]
pub(crate) static TEST_SESSION_LOCK: Mutex<()> = Mutex::new(());

#[cfg(test)]
mod tests {
    use super::*;

    fn lock() -> std::sync::MutexGuard<'static, ()> {
        TEST_SESSION_LOCK.lock().unwrap_or_else(|e| e.into_inner())
    }

    #[test]
    fn disabled_probes_record_nothing() {
        let _g = lock();
        {
            let _untracked = span(SpanCat::Task, "before-session");
            counter("queue depth", 3);
        }
        let session = TraceSession::start();
        let trace = session.finish();
        assert_eq!(trace.span_count(), 0, "{trace:?}");
    }

    #[test]
    fn spans_and_counters_round_trip_through_a_session() {
        let _g = lock();
        let session = TraceSession::start();
        {
            let mut s = span_arg(SpanCat::Stage, "stage", 7);
            s.set_arg(9);
            let _inner = span(SpanCat::Map, "map-phase");
            counter("cache bytes", 1234);
        }
        let trace = session.finish();
        assert_eq!(trace.span_count(), 2);
        let t = &trace.threads[0];
        assert_eq!(t.counters.len(), 1);
        assert_eq!(t.counters[0].value, 1234);
        // Inner span drops first; the outer stage span carries the
        // updated arg and spans at least its child's duration.
        let stage = t.spans.iter().find(|s| s.cat == SpanCat::Stage).unwrap();
        let map = t.spans.iter().find(|s| s.cat == SpanCat::Map).unwrap();
        assert_eq!(stage.arg, 9);
        assert!(stage.dur_ns >= map.dur_ns);
        assert!(stage.t0_ns <= map.t0_ns);
    }

    #[test]
    fn each_thread_gets_its_own_track() {
        let _g = lock();
        let session = TraceSession::start();
        std::thread::scope(|scope| {
            for _ in 0..3 {
                scope.spawn(|| {
                    let _s = span(SpanCat::Task, "task");
                });
            }
        });
        let _driver = span(SpanCat::Stage, "stage");
        drop(_driver);
        let trace = session.finish();
        assert_eq!(trace.threads.len(), 4);
        let tids: std::collections::HashSet<u64> =
            trace.threads.iter().map(|t| t.tid).collect();
        assert_eq!(tids.len(), 4, "tids must be unique");
    }

    #[test]
    fn capacity_overflow_is_counted_not_grown() {
        let _g = lock();
        let session = TraceSession::start_with_capacity(4);
        for _ in 0..10 {
            let _s = span(SpanCat::Task, "task");
        }
        let trace = session.finish();
        assert_eq!(trace.span_count(), 4);
        assert_eq!(trace.dropped(), 6);
    }

    #[test]
    fn a_new_session_does_not_inherit_old_buffers() {
        let _g = lock();
        let first = TraceSession::start();
        {
            let _s = span(SpanCat::Task, "first");
        }
        let t1 = first.finish();
        assert_eq!(t1.span_count(), 1);
        let second = TraceSession::start();
        {
            let _s = span(SpanCat::Task, "second");
        }
        let t2 = second.finish();
        assert_eq!(t2.span_count(), 1);
        assert_eq!(t2.threads[0].spans[0].name, "second");
    }
}
