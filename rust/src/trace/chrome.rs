//! Chrome trace-event JSON export — and the parser that validates it.
//!
//! [`render`] turns a drained [`Trace`] into the JSON object format of
//! the Trace Event spec: `{"traceEvents":[…]}` with
//!
//! * one `M`/`thread_name` metadata event per recorded thread, so
//!   Perfetto labels each track with its OS thread name
//!   (`blaze-exec-3`, `main`, …);
//! * one complete (`"ph":"X"`) duration event per span — timestamps are
//!   microseconds with nanosecond decimals, `cat` is the
//!   [`SpanCat`](super::SpanCat) label, `args.arg` carries the
//!   category-specific payload;
//! * one counter (`"ph":"C"`) event per [`CounterEvent`] sample — these
//!   become the "cache bytes"/"queue depth" counter tracks.
//!
//! Load the file with **Perfetto** (<https://ui.perfetto.dev> → "Open
//! trace file") or `chrome://tracing` → "Load".
//!
//! Because the repo is zero-dependency, the reader half ([`parse`],
//! [`validate`]) is a small hand-rolled JSON parser; the trace-schema
//! tests and the `blaze trace-check` CLI both go through it, so every
//! event we emit is proven to parse back.

use std::collections::BTreeMap;
use std::io::Write;
use std::path::Path;

use super::Trace;

/// The process id every event is emitted under (single-process tool).
const PID: u64 = 1;

fn escape_json(s: &str, out: &mut String) {
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
}

/// Nanoseconds → the spec's microsecond timestamps, keeping ns precision.
fn micros(ns: u64) -> String {
    format!("{}.{:03}", ns / 1_000, ns % 1_000)
}

/// Render a drained trace as a Chrome trace-event JSON string.
pub fn render(trace: &Trace) -> String {
    let mut out = String::with_capacity(64 * 1024);
    out.push_str("{\"traceEvents\":[");
    let mut first = true;
    let mut push = |out: &mut String, event: String| {
        if !std::mem::take(&mut first) {
            out.push(',');
        }
        out.push('\n');
        out.push_str(&event);
    };
    for t in &trace.threads {
        let mut name = String::new();
        escape_json(&t.name, &mut name);
        push(
            &mut out,
            format!(
                "{{\"ph\":\"M\",\"name\":\"thread_name\",\"pid\":{PID},\"tid\":{},\
                 \"args\":{{\"name\":\"{name}\"}}}}",
                t.tid
            ),
        );
    }
    for t in &trace.threads {
        for s in &t.spans {
            let mut name = String::new();
            escape_json(s.name, &mut name);
            push(
                &mut out,
                format!(
                    "{{\"ph\":\"X\",\"name\":\"{name}\",\"cat\":\"{}\",\"pid\":{PID},\
                     \"tid\":{},\"ts\":{},\"dur\":{},\"args\":{{\"arg\":{}}}}}",
                    s.cat.label(),
                    t.tid,
                    micros(s.t0_ns),
                    micros(s.dur_ns),
                    s.arg
                ),
            );
        }
        for c in &t.counters {
            let mut name = String::new();
            escape_json(c.name, &mut name);
            push(
                &mut out,
                format!(
                    "{{\"ph\":\"C\",\"name\":\"{name}\",\"pid\":{PID},\"tid\":{},\
                     \"ts\":{},\"args\":{{\"value\":{}}}}}",
                    t.tid,
                    micros(c.t_ns),
                    c.value
                ),
            );
        }
    }
    out.push_str("\n]}\n");
    out
}

/// Render and write `trace` to `path`.
pub fn write_file(path: &Path, trace: &Trace) -> std::io::Result<()> {
    let mut f = std::fs::File::create(path)?;
    f.write_all(render(trace).as_bytes())?;
    f.flush()
}

/// One event read back from a trace file.
#[derive(Clone, Debug, PartialEq)]
pub struct ParsedEvent {
    /// Phase: `M` metadata, `X` complete span, `C` counter.
    pub ph: char,
    pub name: String,
    pub cat: String,
    pub pid: u64,
    pub tid: u64,
    /// Microseconds (0 for metadata events).
    pub ts: f64,
    /// Microseconds; `X` events only.
    pub dur: f64,
    /// `args.arg` (spans), `args.value` (counters), `args.name`
    /// (thread-name metadata) — whichever the phase carries.
    pub arg: Option<f64>,
    pub thread_name: Option<String>,
}

/// Minimal JSON value for the hand-rolled reader.
#[derive(Clone, Debug)]
enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn new(s: &'a str) -> Self {
        Parser { bytes: s.as_bytes(), pos: 0 }
    }

    fn err(&self, msg: &str) -> String {
        format!("{msg} at byte {}", self.pos)
    }

    fn skip_ws(&mut self) {
        while let Some(&b) = self.bytes.get(self.pos) {
            if b == b' ' || b == b'\t' || b == b'\n' || b == b'\r' {
                self.pos += 1;
            } else {
                break;
            }
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn eat(&mut self, b: u8) -> Result<(), String> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(_) => self.number(),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json, String> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{lit}'")))
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.eat(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.eat(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b'r') => out.push('\r'),
                        Some(b't') => out.push('\t'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            let hex = self
                                .bytes
                                .get(self.pos + 1..self.pos + 5)
                                .ok_or_else(|| self.err("truncated \\u escape"))?;
                            let hex = std::str::from_utf8(hex)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar (input is a &str, so byte
                    // boundaries are valid).
                    let start = self.pos;
                    self.pos += 1;
                    while self
                        .bytes
                        .get(self.pos)
                        .is_some_and(|&b| b & 0xC0 == 0x80)
                    {
                        self.pos += 1;
                    }
                    out.push_str(std::str::from_utf8(&self.bytes[start..self.pos]).unwrap());
                }
                None => return Err(self.err("unterminated string")),
            }
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.pos;
        while self
            .peek()
            .is_some_and(|b| b.is_ascii_digit() || matches!(b, b'-' | b'+' | b'.' | b'e' | b'E'))
        {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

/// Parse a Chrome trace-event JSON document (object form) back into its
/// events. Errors name the first malformed construct.
pub fn parse(json: &str) -> Result<Vec<ParsedEvent>, String> {
    let mut p = Parser::new(json);
    let root = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(p.err("trailing garbage after document"));
    }
    let events = root
        .get("traceEvents")
        .ok_or("missing 'traceEvents' key")?;
    let Json::Arr(items) = events else {
        return Err("'traceEvents' is not an array".into());
    };
    let mut out = Vec::with_capacity(items.len());
    for (i, item) in items.iter().enumerate() {
        let ph = item
            .get("ph")
            .and_then(Json::as_str)
            .ok_or(format!("event {i}: missing 'ph'"))?;
        let ph = ph.chars().next().ok_or(format!("event {i}: empty 'ph'"))?;
        let name = item
            .get("name")
            .and_then(Json::as_str)
            .ok_or(format!("event {i}: missing 'name'"))?
            .to_string();
        let num = |key: &str| item.get(key).and_then(Json::as_f64);
        let pid = num("pid").ok_or(format!("event {i}: missing 'pid'"))? as u64;
        let tid = num("tid").ok_or(format!("event {i}: missing 'tid'"))? as u64;
        let args = item.get("args");
        out.push(ParsedEvent {
            ph,
            name,
            cat: item
                .get("cat")
                .and_then(Json::as_str)
                .unwrap_or("")
                .to_string(),
            pid,
            tid,
            ts: num("ts").unwrap_or(0.0),
            dur: num("dur").unwrap_or(0.0),
            arg: args.and_then(|a| {
                a.get("arg").and_then(Json::as_f64).or_else(|| a.get("value").and_then(Json::as_f64))
            }),
            thread_name: args
                .and_then(|a| a.get("name"))
                .and_then(Json::as_str)
                .map(str::to_string),
        });
    }
    Ok(out)
}

/// What [`validate`] proved about a trace file.
#[derive(Clone, Debug, Default)]
pub struct TraceSummary {
    pub events: usize,
    pub span_events: usize,
    pub counter_events: usize,
    /// Distinct `tid`s carrying at least one span.
    pub span_threads: usize,
    /// Distinct counter track names.
    pub counter_tracks: Vec<String>,
    /// Thread names from metadata events, by tid.
    pub thread_names: BTreeMap<u64, String>,
}

/// Schema-check a trace document: parses every event and enforces the
/// invariants the exporter promises (every `X` span names a valid
/// category and non-negative duration; every span's thread has a
/// `thread_name` metadata record; counters carry values). Returns a
/// summary of what the file contains.
pub fn validate(json: &str) -> Result<TraceSummary, String> {
    let events = parse(json)?;
    let mut summary = TraceSummary { events: events.len(), ..Default::default() };
    let mut span_tids = std::collections::BTreeSet::new();
    for (i, e) in events.iter().enumerate() {
        match e.ph {
            'M' => {
                if e.name == "thread_name" {
                    let name = e
                        .thread_name
                        .clone()
                        .ok_or(format!("event {i}: thread_name without args.name"))?;
                    summary.thread_names.insert(e.tid, name);
                }
            }
            'X' => {
                if e.dur < 0.0 || e.ts < 0.0 {
                    return Err(format!("event {i}: negative ts/dur"));
                }
                if e.cat.is_empty() {
                    return Err(format!("event {i}: span without category"));
                }
                summary.span_events += 1;
                span_tids.insert(e.tid);
            }
            'C' => {
                if e.arg.is_none() {
                    return Err(format!("event {i}: counter without args.value"));
                }
                summary.counter_events += 1;
                if !summary.counter_tracks.contains(&e.name) {
                    summary.counter_tracks.push(e.name.clone());
                }
            }
            other => return Err(format!("event {i}: unknown phase '{other}'")),
        }
    }
    for tid in &span_tids {
        if !summary.thread_names.contains_key(tid) {
            return Err(format!("tid {tid} has spans but no thread_name metadata"));
        }
    }
    summary.span_threads = span_tids.len();
    Ok(summary)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::{CounterEvent, SpanCat, SpanEvent, ThreadTrace};

    fn sample_trace() -> Trace {
        Trace {
            threads: vec![
                ThreadTrace {
                    tid: 0,
                    name: "main".into(),
                    spans: vec![SpanEvent {
                        cat: SpanCat::Stage,
                        name: "stage",
                        arg: 2,
                        t0_ns: 1_500,
                        dur_ns: 2_000_123,
                    }],
                    counters: vec![CounterEvent { name: "cache bytes", t_ns: 10, value: 42 }],
                    dropped: 0,
                },
                ThreadTrace {
                    tid: 1,
                    name: "blaze-exec-0".into(),
                    spans: vec![SpanEvent {
                        cat: SpanCat::Task,
                        name: "task \"quoted\"",
                        arg: 0,
                        t0_ns: 0,
                        dur_ns: 7,
                    }],
                    counters: vec![],
                    dropped: 0,
                },
            ],
        }
    }

    #[test]
    fn rendered_trace_parses_back_event_for_event() {
        let trace = sample_trace();
        let events = parse(&render(&trace)).unwrap();
        // 2 metadata + 2 spans + 1 counter.
        assert_eq!(events.len(), 5);
        let span = events.iter().find(|e| e.cat == "stage").unwrap();
        assert_eq!(span.ph, 'X');
        assert_eq!(span.arg, Some(2.0));
        assert!((span.ts - 1.5).abs() < 1e-9);
        assert!((span.dur - 2000.123).abs() < 1e-9);
        let quoted = events.iter().find(|e| e.name.contains("quoted")).unwrap();
        assert_eq!(quoted.name, "task \"quoted\"");
    }

    #[test]
    fn validate_summarizes_tracks() {
        let s = validate(&render(&sample_trace())).unwrap();
        assert_eq!(s.span_events, 2);
        assert_eq!(s.span_threads, 2);
        assert_eq!(s.counter_events, 1);
        assert_eq!(s.counter_tracks, vec!["cache bytes".to_string()]);
        assert_eq!(s.thread_names[&1], "blaze-exec-0");
    }

    #[test]
    fn validate_rejects_malformed_documents() {
        assert!(validate("not json").is_err());
        assert!(validate("{}").is_err());
        assert!(validate("{\"traceEvents\":3}").is_err());
        // A span on a thread with no thread_name metadata.
        let bad = "{\"traceEvents\":[{\"ph\":\"X\",\"name\":\"t\",\"cat\":\"task\",\
                    \"pid\":1,\"tid\":9,\"ts\":0,\"dur\":1}]}";
        assert!(validate(bad).unwrap_err().contains("tid 9"));
    }

    #[test]
    fn parser_handles_escapes_and_nesting() {
        let events = parse(
            "{\"traceEvents\":[{\"ph\":\"M\",\"name\":\"thread_name\",\"pid\":1,\
             \"tid\":0,\"args\":{\"name\":\"a\\u0041\\n\"}}]}",
        )
        .unwrap();
        assert_eq!(events[0].thread_name.as_deref(), Some("aA\n"));
    }

    #[test]
    fn empty_trace_is_valid() {
        let s = validate(&render(&Trace::default())).unwrap();
        assert_eq!(s.events, 0);
    }
}
