//! Typed key/value metrics — the structured replacement for the stringly
//! `detail: String` fields that `JobReport`/`StageOutcome` used to carry.
//!
//! A [`MetricSet`] is an *ordered* list of `key → value` pairs whose
//! [`Display`](std::fmt::Display) renders exactly the `key=value`
//! space-joined lines the old free-form strings contained, so every
//! existing `println!("detail: {}", r.detail)` call site prints the same
//! bytes — while consumers (the cost model on the ROADMAP, `blaze
//! profile`, benches) read individual metrics by name instead of parsing
//! prose. Values keep their *unit* ([`MetricValue`]) so rendering is
//! stable: seconds print as `{:.3}s`, byte counts through
//! [`fmt_bytes`](crate::util::stats::fmt_bytes), counts as plain
//! integers.

use crate::util::stats::fmt_bytes;

/// One metric value with its unit. The unit drives rendering only —
/// accessors expose the raw number.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum MetricValue {
    /// A plain count (`{}`).
    U64(u64),
    /// A unitless ratio/score (`{:.3}`).
    F64(f64),
    /// Wall/CPU seconds (`{:.3}s`).
    Secs(f64),
    /// A byte count (rendered via [`fmt_bytes`]).
    Bytes(u64),
}

impl MetricValue {
    /// The value as `f64` regardless of unit.
    pub fn as_f64(self) -> f64 {
        match self {
            MetricValue::U64(v) | MetricValue::Bytes(v) => v as f64,
            MetricValue::F64(v) | MetricValue::Secs(v) => v,
        }
    }

    /// The value as `u64` (float units truncate).
    pub fn as_u64(self) -> u64 {
        match self {
            MetricValue::U64(v) | MetricValue::Bytes(v) => v,
            MetricValue::F64(v) | MetricValue::Secs(v) => v as u64,
        }
    }
}

impl std::fmt::Display for MetricValue {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            MetricValue::U64(v) => write!(f, "{v}"),
            MetricValue::F64(v) => write!(f, "{v:.3}"),
            MetricValue::Secs(v) => write!(f, "{v:.3}s"),
            MetricValue::Bytes(v) => write!(f, "{}", fmt_bytes(*v)),
        }
    }
}

/// An ordered set of named metrics. Insertion order is rendering order;
/// re-setting an existing key updates it in place.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct MetricSet {
    entries: Vec<(String, MetricValue)>,
}

impl MetricSet {
    pub fn new() -> Self {
        Self::default()
    }

    /// Set `key` to `value` (updates in place if present, else appends).
    pub fn set(&mut self, key: impl Into<String>, value: MetricValue) {
        let key = key.into();
        match self.entries.iter_mut().find(|(k, _)| *k == key) {
            Some((_, v)) => *v = value,
            None => self.entries.push((key, value)),
        }
    }

    /// Builder-style [`set`](Self::set).
    pub fn with(mut self, key: impl Into<String>, value: MetricValue) -> Self {
        self.set(key, value);
        self
    }

    pub fn with_count(self, key: impl Into<String>, v: u64) -> Self {
        self.with(key, MetricValue::U64(v))
    }

    pub fn with_secs(self, key: impl Into<String>, v: f64) -> Self {
        self.with(key, MetricValue::Secs(v))
    }

    pub fn with_bytes(self, key: impl Into<String>, v: u64) -> Self {
        self.with(key, MetricValue::Bytes(v))
    }

    pub fn set_count(&mut self, key: impl Into<String>, v: u64) {
        self.set(key, MetricValue::U64(v));
    }

    pub fn set_secs(&mut self, key: impl Into<String>, v: f64) {
        self.set(key, MetricValue::Secs(v));
    }

    pub fn set_bytes(&mut self, key: impl Into<String>, v: u64) {
        self.set(key, MetricValue::Bytes(v));
    }

    pub fn set_ratio(&mut self, key: impl Into<String>, v: f64) {
        self.set(key, MetricValue::F64(v));
    }

    pub fn get(&self, key: &str) -> Option<MetricValue> {
        self.entries.iter().find(|(k, _)| k == key).map(|(_, v)| *v)
    }

    /// Raw `u64` of a metric (0 when absent).
    pub fn count(&self, key: &str) -> u64 {
        self.get(key).map_or(0, MetricValue::as_u64)
    }

    /// Raw `f64` of a metric (0.0 when absent).
    pub fn value(&self, key: &str) -> f64 {
        self.get(key).map_or(0.0, MetricValue::as_f64)
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn iter(&self) -> impl Iterator<Item = (&str, MetricValue)> {
        self.entries.iter().map(|(k, v)| (k.as_str(), *v))
    }

    /// Append every metric of `other` under `prefix.` (chained jobs fold
    /// per-stage sets into one report-level set this way).
    pub fn merge_prefixed(&mut self, prefix: &str, other: &MetricSet) {
        for (k, v) in other.iter() {
            self.set(format!("{prefix}.{k}"), v);
        }
    }
}

impl std::fmt::Display for MetricSet {
    /// `key=value` pairs, space-joined, in insertion order — byte-for-byte
    /// what the old hand-formatted detail strings produced.
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        for (i, (k, v)) in self.entries.iter().enumerate() {
            if i > 0 {
                write!(f, " ")?;
            }
            write!(f, "{k}={v}")?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_like_the_old_detail_strings() {
        let mut m = MetricSet::new();
        m.set_secs("map", 0.1234);
        m.set_secs("shuffle", 0.05);
        m.set_count("reruns", 0);
        assert_eq!(m.to_string(), "map=0.123s shuffle=0.050s reruns=0");
    }

    #[test]
    fn bytes_render_via_fmt_bytes() {
        let mut m = MetricSet::new();
        m.set_bytes("shuffle_out", 3 << 20);
        assert_eq!(m.to_string(), format!("shuffle_out={}", fmt_bytes(3 << 20)));
    }

    #[test]
    fn set_updates_in_place_preserving_order() {
        let mut m = MetricSet::new();
        m.set_count("a", 1);
        m.set_count("b", 2);
        m.set_count("a", 9);
        assert_eq!(m.to_string(), "a=9 b=2");
        assert_eq!(m.count("a"), 9);
        assert_eq!(m.len(), 2);
    }

    #[test]
    fn prefixed_merge_namespaces_keys() {
        let mut inner = MetricSet::new();
        inner.set_secs("map", 1.0);
        let mut outer = MetricSet::new();
        outer.merge_prefixed("stage0", &inner);
        assert_eq!(outer.value("stage0.map"), 1.0);
    }

    #[test]
    fn accessors_default_to_zero() {
        let m = MetricSet::new();
        assert_eq!(m.count("missing"), 0);
        assert_eq!(m.value("missing"), 0.0);
        assert!(m.get("missing").is_none());
        assert!(m.is_empty());
    }
}
