//! Fold a drained [`Trace`] into the per-stage / per-phase breakdown
//! behind `blaze profile`.
//!
//! The timeline arrives as raw spans on many threads; this module
//! answers the questions a person tuning a run actually asks:
//!
//! * **Where did the wall time go, per stage and per phase?** Each
//!   non-stage span is attributed to the [`Stage`](SpanCat::Stage) span
//!   whose interval contains its midpoint, then grouped by category.
//!   `wall_secs` is the *union* of the group's intervals (overlapping
//!   node/worker spans don't double-count); `busy_secs` is their sum
//!   (total thread-time spent in the phase — `busy/wall` ≈ the phase's
//!   effective parallelism).
//! * **What bounded the run?** [`ProfileReport::critical_path`] chains
//!   each stage's dominant phase (plus the driver-side bridge work
//!   between stages) — the sequence of phases whose speedup would
//!   actually move the end-to-end wall.
//!
//! Worker utilization and steal imbalance come from
//! [`ExecMetrics`](crate::runtime::executor::ExecMetrics) rather than
//! the trace (the executor counts busy/idle nanos whether or not a
//! session is recording); `blaze profile` prints both views side by
//! side.

use std::collections::BTreeMap;

use super::{SpanCat, Trace};

/// One (stage, phase) aggregate.
#[derive(Clone, Debug)]
pub struct PhaseRow {
    /// Stage id the phase spans fell inside, `None` for work outside any
    /// stage span (driver bridges, cross-stage storage activity).
    pub stage: Option<u64>,
    /// [`SpanCat`] label.
    pub phase: &'static str,
    /// Union of the group's span intervals — occupied wall clock.
    pub wall_secs: f64,
    /// Sum of span durations — total thread-seconds in the phase.
    pub busy_secs: f64,
    /// Number of spans aggregated.
    pub count: u64,
}

/// One step of the computed critical path.
#[derive(Clone, Debug)]
pub struct CritStep {
    pub stage: Option<u64>,
    pub phase: &'static str,
    pub secs: f64,
}

/// The analyzed profile of one traced run.
#[derive(Clone, Debug, Default)]
pub struct ProfileReport {
    /// Stage-then-phase ordered aggregates.
    pub rows: Vec<PhaseRow>,
    /// Dominant phase per stage, chained with inter-stage driver work.
    pub critical_path: Vec<CritStep>,
    /// Sum of the critical-path step durations.
    pub critical_secs: f64,
    /// First span start → last span end across the whole trace.
    pub span_wall_secs: f64,
    /// Executor tasks observed ([`SpanCat::Task`] spans).
    pub tasks: u64,
}

/// Union length of a set of `[start, end)` intervals, in ns.
fn interval_union_ns(mut intervals: Vec<(u64, u64)>) -> u64 {
    intervals.sort_unstable();
    let mut total = 0u64;
    let mut cur: Option<(u64, u64)> = None;
    for (s, e) in intervals {
        match &mut cur {
            Some((_, ce)) if s <= *ce => *ce = (*ce).max(e),
            _ => {
                if let Some((cs, ce)) = cur.take() {
                    total += ce - cs;
                }
                cur = Some((s, e));
            }
        }
    }
    if let Some((cs, ce)) = cur {
        total += ce - cs;
    }
    total
}

fn secs(ns: u64) -> f64 {
    ns as f64 / 1e9
}

/// Analyze a drained trace. See the module docs for the semantics.
pub fn analyze(trace: &Trace) -> ProfileReport {
    // Stage windows: (t0, t1, stage id), from every Stage span (reruns of
    // one stage merge under the same id through the interval union).
    let mut stages: Vec<(u64, u64, u64)> = Vec::new();
    let mut lo = u64::MAX;
    let mut hi = 0u64;
    for t in &trace.threads {
        for s in &t.spans {
            lo = lo.min(s.t0_ns);
            hi = hi.max(s.t0_ns + s.dur_ns);
            if s.cat == SpanCat::Stage {
                stages.push((s.t0_ns, s.t0_ns + s.dur_ns, s.arg));
            }
        }
    }
    stages.sort_unstable();
    let stage_of = |t0: u64, dur: u64| -> Option<u64> {
        let mid = t0 + dur / 2;
        stages
            .iter()
            .find(|(s, e, _)| mid >= *s && mid < *e)
            .map(|(_, _, id)| *id)
    };

    // Group phase spans by (stage, category).
    let mut groups: BTreeMap<(Option<u64>, &'static str), (Vec<(u64, u64)>, u64, u64)> =
        BTreeMap::new();
    let mut tasks = 0u64;
    for t in &trace.threads {
        for s in &t.spans {
            if s.cat == SpanCat::Stage {
                continue;
            }
            if s.cat == SpanCat::Task {
                tasks += 1;
            }
            let key = (stage_of(s.t0_ns, s.dur_ns), s.cat.label());
            let entry = groups.entry(key).or_insert_with(|| (Vec::new(), 0, 0));
            entry.0.push((s.t0_ns, s.t0_ns + s.dur_ns));
            entry.1 += s.dur_ns;
            entry.2 += 1;
        }
    }

    let rows: Vec<PhaseRow> = groups
        .into_iter()
        .map(|((stage, phase), (intervals, busy_ns, count))| PhaseRow {
            stage,
            phase,
            wall_secs: secs(interval_union_ns(intervals)),
            busy_secs: secs(busy_ns),
            count,
        })
        .collect();

    // Critical path: per stage (in id order) the phase with the largest
    // occupied wall, then the driver-side work outside every stage.
    const CHAINABLE: [&str; 6] =
        ["map", "exchange", "finalize", "spill-run", "spill-merge", "task"];
    let mut critical_path = Vec::new();
    let mut stage_ids: Vec<u64> = stages.iter().map(|(_, _, id)| *id).collect();
    stage_ids.sort_unstable();
    stage_ids.dedup();
    for id in stage_ids {
        let best = rows
            .iter()
            .filter(|r| r.stage == Some(id) && CHAINABLE.contains(&r.phase))
            .max_by(|a, b| a.wall_secs.total_cmp(&b.wall_secs));
        if let Some(r) = best {
            critical_path.push(CritStep { stage: r.stage, phase: r.phase, secs: r.wall_secs });
        }
    }
    for r in rows.iter().filter(|r| r.stage.is_none()) {
        if matches!(r.phase, "bridge" | "driver") {
            critical_path.push(CritStep { stage: None, phase: r.phase, secs: r.wall_secs });
        }
    }
    let critical_secs = critical_path.iter().map(|s| s.secs).sum();

    ProfileReport {
        rows,
        critical_path,
        critical_secs,
        span_wall_secs: if hi > lo { secs(hi - lo) } else { 0.0 },
        tasks,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::{SpanEvent, ThreadTrace};

    fn span(cat: SpanCat, arg: u64, t0: u64, dur: u64) -> SpanEvent {
        SpanEvent { cat, name: cat.label(), arg, t0_ns: t0, dur_ns: dur }
    }

    #[test]
    fn interval_union_merges_overlaps() {
        assert_eq!(interval_union_ns(vec![(0, 10), (5, 15), (20, 30)]), 25);
        assert_eq!(interval_union_ns(vec![]), 0);
        assert_eq!(interval_union_ns(vec![(3, 3)]), 0);
    }

    #[test]
    fn phases_attribute_to_their_containing_stage() {
        let trace = Trace {
            threads: vec![
                ThreadTrace {
                    tid: 0,
                    name: "driver".into(),
                    spans: vec![
                        span(SpanCat::Stage, 0, 0, 1_000),
                        span(SpanCat::Bridge, 0, 1_000, 100),
                        span(SpanCat::Stage, 1, 1_100, 2_000),
                    ],
                    counters: vec![],
                    dropped: 0,
                },
                ThreadTrace {
                    tid: 1,
                    name: "node".into(),
                    spans: vec![
                        span(SpanCat::Map, 0, 100, 500),
                        span(SpanCat::Exchange, 0, 600, 300),
                        span(SpanCat::Map, 0, 1_200, 1_500),
                    ],
                    counters: vec![],
                    dropped: 0,
                },
                ThreadTrace {
                    tid: 2,
                    name: "node2".into(),
                    // Overlaps thread 1's stage-0 map: wall must union.
                    spans: vec![span(SpanCat::Map, 1, 200, 500)],
                    counters: vec![],
                    dropped: 0,
                },
            ],
        };
        let p = analyze(&trace);
        let map0 = p
            .rows
            .iter()
            .find(|r| r.stage == Some(0) && r.phase == "map")
            .unwrap();
        assert_eq!(map0.count, 2);
        assert!((map0.wall_secs - 600e-9).abs() < 1e-15); // union of [100,600) ∪ [200,700)
        assert!((map0.busy_secs - 1000e-9).abs() < 1e-15);
        let map1 = p
            .rows
            .iter()
            .find(|r| r.stage == Some(1) && r.phase == "map")
            .unwrap();
        assert_eq!(map1.count, 1);
        // Critical path: stage 0 dominant phase (map), stage 1 map, then
        // the bridge outside both stages.
        assert_eq!(p.critical_path.len(), 3);
        assert_eq!(p.critical_path[0].phase, "map");
        assert_eq!(p.critical_path[2].phase, "bridge");
        assert!(p.critical_secs > 0.0);
        assert!((p.span_wall_secs - 3_100e-9).abs() < 1e-15);
    }

    #[test]
    fn empty_trace_analyzes_to_empty_report() {
        let p = analyze(&Trace::default());
        assert!(p.rows.is_empty());
        assert!(p.critical_path.is_empty());
        assert_eq!(p.span_wall_secs, 0.0);
    }
}
